//! Failure injection and recovery — the machinery behind §5.4's
//! fail-over experiments (Fig. 7) and the crash-consistency tests.

use std::collections::HashMap;

use crate::fs::{FsError, NodeId, ProcId, Result, SocketId};
use crate::oplog::{LogEntry, LogOp};
use crate::replication::{partition_by_chain, route_partitions, EntryRoute};
use crate::Nanos;

use super::assise::Cluster;

/// Summary of a fail-over/recovery event (virtual-time breakdown).
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// when the failure was injected
    pub failed_at: Nanos,
    /// when the cluster manager declared the failure (heartbeat timeout)
    pub detected_at: Nanos,
    /// when the replacement process could serve its first op
    pub first_op_at: Nanos,
    /// log entries lost to the crash (beyond the replicated prefix)
    pub lost_entries: usize,
}

impl Cluster {
    /// Kill an application process (most common failure, §3.4). The NVM
    /// log survives; volatile state is dropped. Leases are *not* yet
    /// released — the local SharedFS does that during recovery.
    pub fn kill_process(&mut self, pid: ProcId) -> Result<()> {
        self.check_pid(pid)?;
        self.procs[pid].crash_volatile();
        self.san.proc_crash(pid);
        Ok(())
    }

    /// Restart a crashed process on its home node (§3.4 LibFS recovery):
    /// the local SharedFS evicts (digests) the dead LibFS's log —
    /// recovering ALL completed writes, even in optimistic mode — then
    /// expires its leases; the process rebuilds its in-memory state.
    /// Returns the virtual time at which it can serve ops.
    pub fn restart_process(&mut self, pid: ProcId, at: Nanos) -> Result<Nanos> {
        self.check_pid(pid)?;
        if self.procs[pid].alive {
            return Err(FsError::InvalidArgument("process not crashed".into()));
        }
        self.procs[pid].clock.now = at;
        self.procs[pid].rebuild_view(at);
        // local recovery keeps even unreplicated entries: digest the full
        // log (idempotent)
        let tail = self.procs[pid].log.tail_seq();
        self.replicate_log(pid)?;
        self.procs[pid].log.mark_replicated(tail);
        self.digest_log(pid)?;
        // after digest the view duplicates SharedFS state; drop it so
        // reads flow through the shared area
        self.procs[pid].log_view = crate::fs::FileStore::new();
        // lease recovery: grant cost for re-acquisition is charged lazily
        // on next access; SharedFS releases the old leases. Dead nodes
        // have no running SharedFS to sweep — their volatile lease
        // tables come back EMPTY when the node reboots (`recover_node`),
        // so there is nothing to revoke there.
        for node in 0..self.nodes.len() {
            if !self.nodes[node].alive {
                continue;
            }
            for s in 0..self.nodes[node].sockets.len() {
                self.nodes[node].sockets[s].sharedfs.leases.revoke_all(pid);
            }
        }
        Ok(self.procs[pid].clock.now)
    }

    /// Kill a whole node (power/hardware failure). All processes on it
    /// die. A clean kill silences the node completely, so the cluster
    /// manager declares it after one missed heartbeat plus the suspect
    /// window (`heartbeat_interval + suspect_timeout`) and bumps the
    /// epoch; gray failures charge more (see
    /// [`Cluster::suspect_partitioned_node`](super::fault)). Returns
    /// the detection time.
    pub fn kill_node(&mut self, node: NodeId, at: Nanos) -> Result<Nanos> {
        self.check_node_id(node)?;
        self.nodes[node].alive = false;
        for pid in 0..self.procs.len() {
            if self.procs[pid].node == node {
                self.procs[pid].crash_volatile();
                self.san.proc_crash(pid);
            }
        }
        // crash point: every acked prefix must still be recoverable
        // from a live valid copy (the sanitizer's sweep)
        self.san.node_down(node);
        let detected =
            at + self.cfg.heartbeat_interval + self.cfg.suspect_timeout;
        self.mgr.node_failed_at(node, detected);
        self.fault_stats.detection_latency.record(detected.saturating_sub(at));
        // lease management fails over to the chain successor (§3.4)
        if let Some(&succ) = self.mgr.up_nodes().first() {
            self.mgr.fail_over_lease_management(node, (succ, 0));
        }
        Ok(detected)
    }

    /// Fail a process over to a backup cache replica (§3.4, Fig. 7): a
    /// replacement is spawned on `to`, the backup SharedFS takes over,
    /// and the dead process's *replicated* log is evicted there. The
    /// recovery is **shard-aware**: survivors hold, per subtree chain,
    /// only the prefix *that chain* acked — entries beyond their own
    /// chain's cursor are lost (which may leave interior gaps when
    /// chains acked unevenly) — and each surviving partition is digested
    /// on its own chain's replicas, every one of which pays the NVM
    /// log-scan + area-write cost. Returns the new ProcId and a
    /// recovery report.
    pub fn failover_process(
        &mut self,
        pid: ProcId,
        to: NodeId,
        to_socket: SocketId,
        failed_at: Nanos,
    ) -> Result<(ProcId, RecoveryReport)> {
        self.check_pid(pid)?;
        self.check_node_id(to)?;
        let p = self.p();
        let home = self.procs[pid].node;
        // the manager's verdict wins: a node it declared Down (clean
        // kill OR partition-suspected while still running) carries its
        // own detection time. Otherwise a live home means a process-only
        // failure the local OS reports immediately; a dead, undeclared
        // home waits out the heartbeat + suspect window.
        let detected_at = match self.mgr.state(home) {
            crate::cluster::NodeState::Down { detected_at } => detected_at,
            _ if self.nodes[home].alive => failed_at,
            _ => failed_at + self.cfg.heartbeat_interval + self.cfg.suspect_timeout,
        };

        // survivors only have each chain's own acked prefix; a
        // cross-chain rename must have been acked by BOTH its chains
        let route_of: HashMap<u64, EntryRoute> = self.procs[pid]
            .log
            .all()
            .map(|e| {
                let primary = self.mgr.chain_id_for(e.op.path());
                let route = match &e.op {
                    LogOp::Rename { to, .. } => {
                        EntryRoute::two(primary, self.mgr.chain_id_for(to))
                    }
                    _ => EntryRoute::one(primary),
                };
                (e.seq, route)
            })
            .collect();
        let lost: Vec<LogEntry> = self.procs[pid]
            .log
            .truncate_to_replicated_by(|e| route_of.get(&e.seq).copied().unwrap_or_default());

        let new_pid = {
            use crate::sim::api::DistFs;
            self.spawn_process(to, to_socket)
        };
        self.procs[new_pid].clock.now = detected_at;

        // each chain's replicas evict their copy of the dead process's
        // replicated log into their shared areas (near-instantaneous
        // fail-over: this is the only work on the critical path)
        let entries: Vec<LogEntry> = self.procs[pid].log.all().cloned().collect();
        if !entries.is_empty() {
            let parts = partition_by_chain(&entries, |path| {
                (self.mgr.chain_id_for(path), self.area_socket(path))
            });
            // path -> routed chain id, for the per-chain digest
            // watermarks (same grouping digest_log used, so replay of
            // already-digested prefixes stays idempotent)
            let key_of = self.chain_ids_of(&entries);
            let has_xrename = self.has_cross_chain_rename(&entries);
            // a replica serving several chains applies one sorted batch
            let routed = route_partitions(&parts, |part| {
                let chain = self.mgr.live_chain_for(&part.path);
                let reserves = self.mgr.live_reserves_for(&part.path);
                chain
                    .iter()
                    .chain(reserves.iter())
                    .map(|&r| (r, self.clamped_sock(r, part.sock)))
                    .collect()
            });
            let t0 = self.procs[new_pid].clock.now;
            let mut t_done = t0;
            for ((r, sock), batch) in &routed {
                let (r, sock) = (*r, *sock);
                let bytes: u64 = batch.iter().map(|e| e.bytes()).sum();
                // a surviving cross-chain rename may land on a chain
                // whose store never held the source file
                if has_xrename {
                    self.stage_cross_chain_renames(pid, r, sock, batch, &entries, t0)?;
                }
                // every replica scans its local replicated-log copy and
                // writes its shared area (replicas digest in parallel)
                let read_done = self.nodes[r].sockets[sock].nvm.read_log(t0, bytes, &p);
                let write_done = self.nodes[r].sockets[sock].nvm.write(read_done, bytes, &p);
                self.nodes[r].sockets[sock].sharedfs.digest(pid, batch, write_done, |path| {
                    key_of.get(path).copied().unwrap_or_default()
                })?;
                // recovery digests commit synchronously: the objects are
                // immediately clean on every surviving replica
                self.bump_versions(r, sock, batch, write_done, write_done);
                t_done = t_done.max(write_done);
            }
            // pre-migration copies on retired members must not outlive
            // the recovery digest
            self.invalidate_on_retired(&parts);
            self.procs[new_pid].clock.advance_to(t_done);
        }
        // sweep the dead process's leases from every LIVE SharedFS (dead
        // nodes' volatile tables reboot empty in `recover_node`); the
        // replacement re-acquires lazily
        let lease_count = {
            let mut count = 0;
            for node in 0..self.nodes.len() {
                if !self.nodes[node].alive {
                    continue;
                }
                for s in 0..self.nodes[node].sockets.len() {
                    count += self.nodes[node].sockets[s].sharedfs.leases.revoke_all(pid).len();
                }
            }
            count
        };
        self.procs[new_pid]
            .clock
            .tick(lease_count as Nanos * p.syscall_write_lat);

        let report = RecoveryReport {
            failed_at,
            detected_at,
            first_op_at: self.procs[new_pid].clock.now,
            lost_entries: lost.len(),
        };
        Ok((new_pid, report))
    }

    /// Reboot a crashed node and run SharedFS recovery (§3.4 node
    /// recovery): collect epoch bitmaps from a live peer, invalidate
    /// every inode written while down. Returns the time recovery
    /// completes (the node serves — stale inodes refetch lazily).
    pub fn recover_node(&mut self, node: NodeId, at: Nanos) -> Result<Nanos> {
        self.check_node_id(node)?;
        if self.nodes[node].alive {
            return Err(FsError::InvalidArgument("node not down".into()));
        }
        let p = self.p();
        self.nodes[node].alive = true;
        self.san.node_up(node);
        for s in 0..self.nodes[node].sockets.len() {
            self.nodes[node].sockets[s].nvm.reboot();
        }
        self.nodes[node].dram.crash();
        self.nodes[node].ssd.reboot();
        self.nodes[node].cap.reboot();
        self.nodes[node].interconnect.reboot();
        self.fabric.nics[node].reboot();
        // the daemon's per-node memory is volatile: sweep schedule and
        // hysteresis stamps must not gate the rebuilt state copy
        self.tiering.forget_node(node);

        let since = self.mgr.node_recovered(node, at);
        let written = self.mgr.epochs.written_since(since);
        let bitmap_bytes = self.mgr.epochs.bitmap_bytes(since);
        // fetch bitmaps + namespace from a live peer — prefer a
        // configured chain SIBLING: under sharded `set_chain` configs
        // stores legitimately diverge per chain, and resyncing from an
        // arbitrary node would overwrite this node's subtrees with a
        // store that never held them
        let peer = self
            .mgr
            .chain_siblings(node)
            .into_iter()
            .find(|&n| self.mgr.is_up(n))
            .or_else(|| self.mgr.up_nodes().into_iter().find(|&n| n != node))
            .ok_or(FsError::NotFound("no live peer".into()))?;
        let done = self.fault_rpc(at, node, peer, 64, bitmap_bytes.max(64), p.rpc_overhead)?;
        // namespace sync: files created/renamed during the downtime are
        // unknown locally — rebuild the store's *metadata* from the live
        // peer's replicated state (the SharedFS log, §3.4), then
        // invalidate every inode written while down so its DATA is
        // refetched lazily on first access. Inodes untouched during the
        // downtime keep their local NVM contents (that is the whole
        // point of NVM-colocated recovery).
        for s in 0..self.nodes[node].sockets.len() {
            let ps = self.clamped_sock(peer, s);
            let peer_store = self.nodes[peer].sockets[ps].sharedfs.store.clone();
            let peer_applied = self.nodes[peer].sockets[ps].sharedfs.applied_upto.clone();
            // object versions ride with the store: the peer's clean
            // watermarks are exactly what this node's resynced copies are
            let peer_versions = self.nodes[peer].sockets[ps].sharedfs.versions.clone();
            let sfs = &mut self.nodes[node].sockets[s].sharedfs;
            sfs.store = peer_store;
            sfs.applied_upto = peer_applied;
            sfs.versions = peer_versions;
            // replicated-log regions on this node's NVM survived the
            // reboot but their chains may have digested past them while
            // we were down; the copied watermarks make replay idempotent,
            // so drop the GC accounting and let new replication rebuild it
            sfs.repl_log_bytes.clear();
            sfs.invalidate_inos(&written);
            // the daemon's lease table is volatile: it reboots empty
            // (holders re-acquire lazily; stale grants died with the OS)
            sfs.leases = crate::coherence::LeaseTable::new();
            sfs.lease_busy_until = 0;
        }
        // the installed peer copy carries its own tier layout: re-derive
        // this node's SSD/capacity accounting from it (a retired member's
        // copy must not resurrect evicted bytes into stale device gauges)
        if !self.tiering.inert() {
            self.reconcile_tier_devices(node);
        }
        Ok(done)
    }

    /// OS fail-over (§5.4): instead of failing over to a backup node,
    /// reboot the OS locally from an NVM-resident snapshot. The paper
    /// measures 1.66 s VM boot + 0.23 s SharedFS recovery; NVM contents
    /// (logs, shared areas) survive intact, so only volatile state
    /// (DRAM caches, lease tables' in-memory copies) rebuilds. Returns
    /// (time the FS is recovered, report).
    pub fn os_failover(&mut self, node: NodeId, at: Nanos) -> Result<(Nanos, RecoveryReport)> {
        const VM_SNAPSHOT_BOOT: Nanos = 1_660_000_000; // §5.4: 1.66 s
        self.check_node_id(node)?;
        // kill volatile state of every process on the node (the VM died)
        for pid in 0..self.procs.len() {
            if self.procs[pid].node == node {
                self.procs[pid].crash_volatile();
            }
        }
        self.nodes[node].dram.crash();
        let booted = at + VM_SNAPSHOT_BOOT;
        // SharedFS recovery: replay the SharedFS log from NVM (§3.4 "we
        // can use NVM to dramatically accelerate OS reboot") — cost is a
        // sequential NVM scan of the SharedFS log + lease table rebuild
        let p = self.p();
        let mut done = booted;
        for s in 0..self.nodes[node].sockets.len() {
            let log_bytes = self.nodes[node].sockets[s].sharedfs.sfs_log_bytes.max(4096);
            let t = self.nodes[node].sockets[s].nvm.read_log(booted, log_bytes, &p);
            done = done.max(t);
        }
        let report = RecoveryReport {
            failed_at: at,
            detected_at: at, // local crash: detected immediately
            first_op_at: done,
            lost_entries: 0, // NVM logs survive an OS reboot
        };
        Ok((done, report))
    }

    /// Count of stale (to-be-refetched) inodes on a node.
    pub fn stale_inodes(&self, node: NodeId) -> usize {
        self.nodes[node]
            .sockets
            .iter()
            .map(|s| s.sharedfs.stale.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use crate::fs::Payload;
    use crate::sim::api::DistFs;
    use crate::sim::{Cluster, ClusterConfig, CrashMode};

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::default().nodes(2))
    }

    #[test]
    fn process_crash_and_local_restart_recovers_all_writes() {
        let mut c = cluster();
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        c.write(pid, fd, Payload::bytes(b"persisted".to_vec())).unwrap();
        // NOT fsynced — still recovered locally (NVM log survives)
        let t = c.now(pid);
        c.kill_process(pid).unwrap();
        c.restart_process(pid, t + 1_000_000).unwrap();
        let fd2 = c.open(pid, "/f").unwrap();
        let data = c.pread(pid, fd2, 0, 9).unwrap();
        assert_eq!(data.materialize(), b"persisted");
    }

    #[test]
    fn optimistic_local_restart_also_recovers_unreplicated() {
        let mut c = Cluster::new(ClusterConfig::default().nodes(2).mode(CrashMode::Optimistic));
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        c.write(pid, fd, Payload::bytes(b"optim".to_vec())).unwrap();
        c.fsync(pid, fd).unwrap(); // no-op in optimistic mode
        let t = c.now(pid);
        c.kill_process(pid).unwrap();
        c.restart_process(pid, t).unwrap();
        let fd2 = c.open(pid, "/f").unwrap();
        assert_eq!(c.pread(pid, fd2, 0, 5).unwrap().materialize(), b"optim");
    }

    #[test]
    fn node_failover_preserves_replicated_prefix_only() {
        let mut c = cluster();
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        c.write(pid, fd, Payload::bytes(b"synced".to_vec())).unwrap();
        c.fsync(pid, fd).unwrap();
        c.write(pid, fd, Payload::bytes(b"UNSYNCED".to_vec())).unwrap();
        let t = c.now(pid);
        c.kill_node(0, t).unwrap();
        let (np, report) = c.failover_process(pid, 1, 0, t).unwrap();
        assert_eq!(report.lost_entries, 1); // the unsynced write
        assert!(report.detected_at >= t + 1_000_000_000); // 1s heartbeat
        // replicated data visible on the backup
        let fd2 = c.open(np, "/f").unwrap();
        let data = c.pread(np, fd2, 0, 6).unwrap();
        assert_eq!(data.materialize(), b"synced");
        // the unsynced suffix is gone (file is only 6 bytes)
        assert_eq!(c.stat(np, "/f").unwrap().size, 6);
    }

    #[test]
    fn failover_is_fast_after_detection() {
        let mut c = cluster();
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        for _ in 0..100 {
            c.write(pid, fd, Payload::bytes(vec![1u8; 4096])).unwrap();
        }
        c.fsync(pid, fd).unwrap();
        let t = c.now(pid);
        c.kill_node(0, t).unwrap();
        let (_, report) = c.failover_process(pid, 1, 0, t).unwrap();
        // fail-over work after detection ≪ 1 s (paper: 230 ms to full
        // perf for a 1 GB log; here the log is ~400 KB)
        let work = report.first_op_at - report.detected_at;
        assert!(work < 100_000_000, "failover work {work}ns");
    }

    #[test]
    fn node_recovery_invalidates_written_inodes() {
        let mut c = cluster();
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        c.write(pid, fd, Payload::bytes(b"before".to_vec())).unwrap();
        c.fsync(pid, fd).unwrap();
        c.digest_log(pid).unwrap();

        // node 1 goes down; p0 keeps writing
        let t = c.now(pid);
        c.kill_node(1, t).unwrap();
        c.pwrite(pid, fd, 0, Payload::bytes(b"AFTER!".to_vec())).unwrap();
        c.fsync(pid, fd).unwrap();
        c.digest_log(pid).unwrap();

        // node 1 rejoins: the written inode must be stale there
        let t2 = c.now(pid);
        c.recover_node(1, t2).unwrap();
        assert_eq!(c.stale_inodes(1), 1);

        // a reader on node 1 triggers refetch and sees fresh data
        let p2 = c.spawn_process(1, 0);
        c.set_now(p2, t2 + 1_000_000);
        let fd2 = c.open(p2, "/f").unwrap();
        let data = c.pread(p2, fd2, 0, 6).unwrap();
        assert_eq!(data.materialize(), b"AFTER!");
        assert_eq!(c.stale_inodes(1), 0);
    }

    #[test]
    fn restart_requires_crashed_process() {
        let mut c = cluster();
        let pid = c.spawn_process(0, 0);
        assert!(c.restart_process(pid, 0).is_err());
    }

    #[test]
    fn ops_on_dead_node_fail() {
        let mut c = cluster();
        let pid = c.spawn_process(0, 0);
        c.create(pid, "/f").unwrap();
        c.kill_node(0, 0).unwrap();
        assert!(matches!(
            c.create(pid, "/g"),
            Err(crate::fs::FsError::Crashed)
        ));
    }
}
