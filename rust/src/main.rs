//! Assise CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! - `bench <exp|all> [--scale F]` — regenerate a paper table/figure
//!   (see `assise list`);
//! - `list` — list experiments;
//! - `selfcheck` — load the AOT PJRT artifacts and validate the L1
//!   kernels against the in-crate oracles (end-to-end three-layer
//!   smoke test);
//! - `demo` — tiny end-to-end cluster walkthrough;
//! - `lint` — run the repo's invariant linter (same engine as the
//!   `assise-lint` bin; see `tools/lint/`).

use assise::bench::{self, Scale};
use assise::fs::Payload;
use assise::sim::{Cluster, ClusterConfig, DistFs};

#[path = "../../tools/lint/core/mod.rs"]
mod lintcore;

fn usage() -> ! {
    eprintln!(
        "usage: assise <command>\n\
         \n\
         commands:\n\
           bench <exp|all> [--scale F] [--out FILE]   regenerate paper results\n\
           bench perf [--scale F]                     hot-path microbenchmarks -> BENCH_perf.json\n\
           list                                       list experiments\n\
           selfcheck                                  validate AOT kernels (PJRT)\n\
           demo                                       2-node write/replicate/failover demo\n\
           lint [--root DIR] [--write-baseline]       invariant lints (fault routing,\n\
                                                      determinism, panic ratchet, drift)"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("list") => {
            for e in bench::EXPERIMENTS {
                println!("{e}");
            }
        }
        Some("bench") => {
            let exp = args.get(1).cloned().unwrap_or_else(|| usage());
            let mut scale = Scale::default();
            let mut out: Option<String> = None;
            let mut i = 2;
            while i < args.len() {
                match args[i].as_str() {
                    "--scale" => {
                        scale = Scale(args.get(i + 1).and_then(|s| s.parse().ok()).unwrap_or(1.0));
                        i += 2;
                    }
                    "--out" => {
                        out = args.get(i + 1).cloned();
                        i += 2;
                    }
                    other => {
                        eprintln!("unknown flag {other}");
                        usage();
                    }
                }
            }
            let names: Vec<&str> = if exp == "all" {
                bench::EXPERIMENTS.to_vec()
            } else {
                vec![exp.as_str()]
            };
            let mut rendered = String::new();
            for name in names {
                match bench::run(name, scale) {
                    Some(tables) => {
                        for t in tables {
                            t.print();
                            rendered.push_str(&t.render());
                        }
                    }
                    None => {
                        eprintln!("unknown experiment '{name}' (try `assise list`)");
                        std::process::exit(2);
                    }
                }
            }
            if let Some(path) = out {
                if let Err(e) = std::fs::write(&path, rendered) {
                    eprintln!("failed to write {path}: {e}");
                    std::process::exit(2);
                }
                eprintln!("wrote {path}");
            }
        }
        Some("selfcheck") => selfcheck(),
        Some("demo") => {
            if let Err(e) = demo() {
                eprintln!("demo failed: {e}");
                std::process::exit(1);
            }
        }
        Some("lint") => std::process::exit(lintcore::run_cli(&args[1..])),
        _ => usage(),
    }
}

/// End-to-end three-layer check: load the AOT HLO artifacts through
/// PJRT and compare kernel outputs against the pure-Rust oracles.
fn selfcheck() {
    use assise::runtime::{
        checksum_ref, partition_ref, ChecksumExec, PartitionExec, CHECKSUM_WORDS,
    };
    use assise::util::SplitMix64;

    println!("kernel backend: {}", assise::runtime::backend_name());
    println!("artifacts dir: {}", assise::runtime::artifacts_dir().display());
    let mut failures = 0;

    match ChecksumExec::load() {
        Ok(exec) => {
            let mut rng = SplitMix64::new(1);
            let blocks: Vec<Vec<i32>> = (0..8)
                .map(|_| (0..CHECKSUM_WORDS).map(|_| rng.next_u32() as i32).collect())
                .collect();
            let got = exec.checksum_batch(&blocks).expect("execute");
            let ok = got
                .iter()
                .zip(&blocks)
                .all(|(&(s1, s2), b)| (s1, s2) == checksum_ref(b));
            println!(
                "checksum kernel ({}) vs oracle: {}",
                assise::runtime::backend_name(),
                if ok { "OK" } else { "MISMATCH" }
            );
            if !ok {
                failures += 1;
            }
        }
        Err(e) => {
            println!("checksum kernel: FAILED TO LOAD ({e}) — run `make artifacts`");
            failures += 1;
        }
    }

    match PartitionExec::load() {
        Ok(exec) => {
            let mut rng = SplitMix64::new(2);
            let keys: Vec<u32> = (0..10_000).map(|_| rng.next_u32()).collect();
            let (ids, hist) = exec.partition(&keys).expect("execute");
            let (eids, ehist) = partition_ref(&keys);
            let ok = ids == eids && hist == ehist;
            println!(
                "partition kernel ({}) vs oracle: {}",
                assise::runtime::backend_name(),
                if ok { "OK" } else { "MISMATCH" }
            );
            if !ok {
                failures += 1;
            }
        }
        Err(e) => {
            println!("partition kernel: FAILED TO LOAD ({e}) — run `make artifacts`");
            failures += 1;
        }
    }

    std::process::exit(if failures == 0 { 0 } else { 1 });
}

/// Small 2-node demo: write, replicate, digest, fail over, read back.
fn demo() -> assise::fs::Result<()> {
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    let pid = c.spawn_process(0, 0);
    let fd = c.create(pid, "/demo")?;
    c.write(pid, fd, Payload::bytes(b"colocated NVM!".to_vec()))?;
    println!("write latency: {} ns (process-local NVM log)", c.last_latency(pid));
    c.fsync(pid, fd)?;
    println!("fsync latency: {} ns (chain-replicated to node 1)", c.last_latency(pid));
    c.digest_log(pid)?;

    let t = c.now(pid);
    c.kill_node(0, t)?;
    let (np, report) = c.failover_process(pid, 1, 0, t)?;
    println!(
        "node 0 killed at t={} ms; detected {} ms later; fail-over work took {} us",
        t / 1_000_000,
        (report.detected_at - report.failed_at) / 1_000_000,
        (report.first_op_at - report.detected_at) / 1_000,
    );
    let fd2 = c.open(np, "/demo")?;
    let data = c.pread(np, fd2, 0, 14)?;
    println!("read back on backup: {:?}", String::from_utf8_lossy(&data.materialize()));
    assert_eq!(data.materialize(), b"colocated NVM!");
    println!("demo OK");
    Ok(())
}
