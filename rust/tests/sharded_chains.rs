//! Sharded-chain correctness (§3.2 W2, §3.4): with subtrees pinned to
//! disjoint replication chains via `set_chain`, a mixed-subtree fsync
//! batch must be recoverable on **each** subtree's own chain after
//! `kill_node` + `failover_process` — and only there. Keying a batch by
//! its first entry's path (the old behavior) sent every partition down
//! one chain and masked the loss by broadcasting fail-over digests to
//! every live node.

use assise::fs::Payload;
use assise::sim::{Cluster, ClusterConfig, DistFs};

/// writer on node 0; /a pinned to chain [1], /b to chain [2]; node 3 is
/// in no chain at all.
fn sharded() -> (Cluster, usize) {
    let mut c = Cluster::new(ClusterConfig::default().nodes(4));
    c.set_subtree_chain("/a", vec![1], vec![]).unwrap();
    c.set_subtree_chain("/b", vec![2], vec![]).unwrap();
    let pid = c.spawn_process(0, 0);
    c.mkdir(pid, "/a").unwrap();
    c.mkdir(pid, "/b").unwrap();
    (c, pid)
}

#[test]
fn mixed_fsync_batch_recoverable_on_each_subtree_chain() {
    let (mut c, pid) = sharded();
    let fa = c.create(pid, "/a/f").unwrap();
    let fb = c.create(pid, "/b/f").unwrap();
    c.write(pid, fa, Payload::bytes(b"alpha-data".to_vec())).unwrap();
    c.write(pid, fb, Payload::bytes(b"bravo-data".to_vec())).unwrap();
    // ONE mixed-subtree fsync batch covering both chains
    c.fsync(pid, fa).unwrap();
    // a suffix beyond the fsync must be lost on fail-over
    c.write(pid, fa, Payload::bytes(b"UNSYNCED".to_vec())).unwrap();

    let t = c.now(pid);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 1, 0, t).unwrap();
    assert_eq!(report.lost_entries, 1, "exactly the unsynced write is lost");

    // each subtree's fsync'd data is recoverable on ITS chain
    let fa2 = c.open(np, "/a/f").unwrap();
    assert_eq!(c.pread(np, fa2, 0, 10).unwrap().materialize(), b"alpha-data");
    assert_eq!(c.stat(np, "/a/f").unwrap().size, 10, "unsynced suffix gone");
    let fb2 = c.open(np, "/b/f").unwrap();
    assert_eq!(c.pread(np, fb2, 0, 10).unwrap().materialize(), b"bravo-data");

    // ...and ONLY on its chain: fail-over routes per subtree chain, it
    // does not broadcast the dead process's log to every live node
    assert!(c.nodes[1].sockets[0].sharedfs.store.exists("/a/f"));
    assert!(!c.nodes[1].sockets[0].sharedfs.store.exists("/b/f"));
    assert!(c.nodes[2].sockets[0].sharedfs.store.exists("/b/f"));
    assert!(!c.nodes[2].sockets[0].sharedfs.store.exists("/a/f"));
    for path in ["/a/f", "/b/f"] {
        assert!(
            !c.nodes[3].sockets[0].sharedfs.store.exists(path),
            "{path} leaked to a node outside every chain"
        );
    }
}

#[test]
fn uneven_chain_acks_lose_only_their_own_chains_suffix() {
    let (mut c, pid) = sharded();
    let fa = c.create(pid, "/a/f").unwrap();
    let fb = c.create(pid, "/b/f").unwrap();
    c.write(pid, fa, Payload::bytes(vec![1u8; 128])).unwrap();
    c.write(pid, fb, Payload::bytes(vec![2u8; 128])).unwrap();
    c.fsync(pid, fa).unwrap();
    // chain [2] falls behind: /b-only suffix, never fsync'd
    let fg = c.create(pid, "/b/g").unwrap();
    c.write(pid, fg, Payload::bytes(vec![3u8; 128])).unwrap();

    let t = c.now(pid);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 1, 0, t).unwrap();
    assert_eq!(report.lost_entries, 2, "create + write of /b/g");

    // /a is whole, /b keeps its fsync'd prefix, /b/g is gone everywhere
    assert_eq!(c.stat(np, "/a/f").unwrap().size, 128);
    assert_eq!(c.stat(np, "/b/f").unwrap().size, 128);
    assert!(c.stat(np, "/b/g").is_err());
    for n in 0..4 {
        assert!(
            !c.nodes[n].sockets[0].sharedfs.store.exists("/b/g"),
            "unreplicated /b/g resurrected on node {n}"
        );
    }
}

#[test]
fn interleaved_fsyncs_keep_per_chain_cursors_exact() {
    // alternating per-subtree fsyncs: each one covers a suffix that is
    // pure /a or pure /b plus the other chain's residue; cursors must
    // track each chain independently through several rounds
    let (mut c, pid) = sharded();
    let fa = c.create(pid, "/a/f").unwrap();
    let fb = c.create(pid, "/b/f").unwrap();
    let mut alen = 0u64;
    let mut blen = 0u64;
    for round in 0..6u64 {
        c.pwrite(pid, fa, alen, Payload::bytes(vec![round as u8; 64])).unwrap();
        alen += 64;
        c.pwrite(pid, fb, blen, Payload::bytes(vec![round as u8; 96])).unwrap();
        blen += 96;
        c.fsync(pid, if round % 2 == 0 { fa } else { fb }).unwrap();
    }
    let t = c.now(pid);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 1, 0, t).unwrap();
    assert_eq!(report.lost_entries, 0, "every round ended fsync'd");
    assert_eq!(c.stat(np, "/a/f").unwrap().size, alen);
    assert_eq!(c.stat(np, "/b/f").unwrap().size, blen);
}
