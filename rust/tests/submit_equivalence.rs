//! Batch/sequential equivalence of the submission-queue API.
//!
//! For each of the four systems (Assise + Ceph/NFS/Octopus baselines),
//! the same deterministic op script is executed twice on two freshly
//! built instances:
//!
//! - **sequential**: one-element batches (the per-op POSIX shims);
//! - **batched**: the identical op stream chopped into random-size
//!   submission rings.
//!
//! The property: every completion carries the same result signature
//! (success kind + payload bytes, or error class), and the final store
//! state — observed purely through the `DistFs` API (stat / readdir /
//! full-content preads over the whole path universe) — is identical.
//! Batching may only change *virtual time*, never state.
//!
//! The script runs a single process: batches are a per-process
//! submission ring (io_uring semantics), and cross-process lease
//! revocation ordering is intentionally out of scope here (covered by
//! the lease tests).

use assise::baselines::{CephLike, NfsLike, OctopusLike};
use assise::fs::{Fd, FsError, Payload};
use assise::sim::api::{DistFs, FsOp, FsOut};
use assise::sim::{Cluster, ClusterConfig};
use assise::util::SplitMix64;

/// Error class only — paths inside errors may legitimately differ in
/// normalization, and timing never appears in errors.
fn err_class(e: &FsError) -> &'static str {
    match e {
        FsError::NotFound(_) => "ENOENT",
        FsError::AlreadyExists(_) => "EEXIST",
        FsError::NotADirectory(_) => "ENOTDIR",
        FsError::IsADirectory(_) => "EISDIR",
        FsError::NotEmpty(_) => "ENOTEMPTY",
        FsError::PermissionDenied(_) => "EACCES",
        FsError::BadFd(_) => "EBADF",
        FsError::NoSpace => "ENOSPC",
        FsError::LeaseConflict(_) => "ELEASE",
        FsError::Crashed => "ECRASHED",
        FsError::ChainUnavailable(_) => "EHOSTDOWN",
        FsError::NotSupported(_) => "ENOTSUP",
        FsError::InvalidArgument(_) => "EINVAL",
    }
}

/// Timing-free signature of one completion result.
fn sig(r: &Result<FsOut, FsError>) -> String {
    match r {
        Ok(FsOut::Unit) => "ok".into(),
        Ok(FsOut::Fd(fd)) => format!("fd:{fd}"),
        Ok(FsOut::Data(d)) => {
            let bytes = d.materialize();
            let sum: u64 = bytes.iter().map(|&b| b as u64).sum();
            format!("data:{}:{sum}", bytes.len())
        }
        Ok(FsOut::Stat(st)) => format!("stat:{}:{}", st.size, st.is_dir),
        Ok(FsOut::Names(v)) => format!("names:{v:?}"),
        Err(e) => format!("err:{}", err_class(e)),
    }
}

/// Deterministic op script over a small path/fd universe. Fds 3..=10
/// are pre-opened by the setup prologue on every instance (same script
/// => same fd numbering), later ops may also close/reopen them.
fn script(seed: u64, len: usize) -> Vec<FsOp> {
    let mut rng = SplitMix64::new(seed);
    let dirs = ["/d0", "/d1", "/d0/sub"];
    let files = ["/d0/a", "/d0/b", "/d1/c", "/d0/sub/d", "/top"];
    let mut ops: Vec<FsOp> = Vec::with_capacity(len + 16);
    // prologue: namespace + one open fd per file (fds 3..=7)
    for d in dirs {
        ops.push(FsOp::Mkdir { path: d.into() });
    }
    for f in files {
        ops.push(FsOp::Create { path: f.into() });
    }
    let fds: Vec<Fd> = (3..3 + files.len() as Fd).collect();
    for _ in 0..len {
        let fd = fds[rng.below(fds.len() as u64) as usize];
        let path = files[rng.below(files.len() as u64) as usize];
        match rng.below(12) {
            0 => {
                let data = Payload::synthetic(rng.next_u64(), 1 + rng.below(6000));
                ops.push(FsOp::Write { fd, data });
            }
            1 => ops.push(FsOp::Pwrite {
                fd,
                off: rng.below(16 << 10),
                data: Payload::synthetic(rng.next_u64(), 1 + rng.below(6000)),
            }),
            2 => ops.push(FsOp::Writev {
                fd,
                bufs: (0..1 + rng.below(3))
                    .map(|_| Payload::synthetic(rng.next_u64(), 1 + rng.below(2000)))
                    .collect(),
            }),
            3 => ops.push(FsOp::Read { fd, len: 1 + rng.below(8000) }),
            4 => ops.push(FsOp::Pread { fd, off: rng.below(16 << 10), len: 1 + rng.below(8000) }),
            5 => ops.push(FsOp::Fsync { fd }),
            6 => ops.push(FsOp::Dsync { fd }),
            7 => ops.push(FsOp::Stat { path: path.into() }),
            8 => {
                let dir = dirs[rng.below(dirs.len() as u64) as usize];
                ops.push(FsOp::Readdir { path: dir.into() });
            }
            9 => ops.push(FsOp::Truncate { path: path.into(), size: rng.below(8 << 10) }),
            10 => ops.push(FsOp::Rename { from: path.into(), to: "/d1/renamed".into() }),
            _ => {
                // create/unlink churn on a dedicated path so fd-backed
                // files stay resolvable for the open prologue
                if rng.below(2) == 0 {
                    ops.push(FsOp::Create { path: "/d1/tmp".into() });
                } else {
                    ops.push(FsOp::Unlink { path: "/d1/tmp".into() });
                }
            }
        }
    }
    ops
}

/// Run `ops` against `fs`, either per-op (batch 0) or chopped into
/// random rings of 2..=9 ops; returns every completion signature.
fn drive(fs: &mut dyn DistFs, pid: usize, ops: &[FsOp], batch_seed: Option<u64>) -> Vec<String> {
    let mut out = Vec::with_capacity(ops.len());
    match batch_seed {
        None => {
            for op in ops {
                for cq in fs.submit(pid, vec![op.clone()]) {
                    out.push(sig(&cq.result));
                }
            }
        }
        Some(seed) => {
            let mut rng = SplitMix64::new(seed);
            let mut i = 0;
            while i < ops.len() {
                let n = (2 + rng.below(8) as usize).min(ops.len() - i);
                let ring: Vec<FsOp> = ops[i..i + n].to_vec();
                i += n;
                for cq in fs.submit(pid, ring) {
                    out.push(sig(&cq.result));
                }
            }
        }
    }
    out
}

/// Observe the final state purely through the API: stat + readdir +
/// full-content reads over the whole path universe.
fn observe(fs: &mut dyn DistFs, pid: usize) -> Vec<String> {
    let mut out = Vec::new();
    for p in [
        "/", "/d0", "/d1", "/d0/sub", "/d0/a", "/d0/b", "/d1/c", "/d0/sub/d", "/top",
        "/d1/renamed", "/d1/tmp",
    ] {
        match fs.stat(pid, p) {
            Ok(st) if st.is_dir => {
                let names = fs.readdir(pid, p).map(|v| format!("{v:?}"));
                out.push(format!("{p} dir {:?}", names.map_err(|e| err_class(&e))));
            }
            Ok(st) => {
                let content = fs
                    .open(pid, p)
                    .and_then(|fd| {
                        let d = fs.pread(pid, fd, 0, st.size)?;
                        fs.close(pid, fd)?;
                        Ok(d)
                    })
                    .map(|d| {
                        let b = d.materialize();
                        let sum: u64 = b.iter().map(|&x| x as u64).sum();
                        format!("{}:{sum}", b.len())
                    });
                let content = content.map_err(|e| err_class(&e));
                out.push(format!("{p} file size={} {content:?}", st.size));
            }
            Err(e) => out.push(format!("{p} {}", err_class(&e))),
        }
    }
    out
}

fn check_system(mk: impl Fn() -> Box<dyn DistFs>, label: &str) {
    for seed in [7u64, 42, 1234] {
        let ops = script(seed, 160);

        let mut seq = mk();
        let sp = seq.spawn_process(0, 0);
        let seq_sigs = drive(seq.as_mut(), sp, &ops, None);

        let mut bat = mk();
        let bp = bat.spawn_process(0, 0);
        let bat_sigs = drive(bat.as_mut(), bp, &ops, Some(seed ^ 0xBEEF));

        assert_eq!(sp, bp);
        assert_eq!(seq_sigs.len(), bat_sigs.len());
        for (i, (a, b)) in seq_sigs.iter().zip(&bat_sigs).enumerate() {
            assert_eq!(a, b, "{label} seed {seed}: completion {i} diverged ({:?})", ops[i]);
        }
        assert_eq!(
            observe(seq.as_mut(), sp),
            observe(bat.as_mut(), bp),
            "{label} seed {seed}: final state diverged"
        );
    }
}

#[test]
fn assise_batches_equal_sequential() {
    check_system(
        || Box::new(Cluster::new(ClusterConfig::default().nodes(2))),
        "assise",
    );
}

#[test]
fn assise_optimistic_batches_equal_sequential() {
    use assise::sim::CrashMode;
    check_system(
        || Box::new(Cluster::new(ClusterConfig::default().nodes(3).mode(CrashMode::Optimistic))),
        "assise-optimistic",
    );
}

#[test]
fn nfs_batches_equal_sequential() {
    check_system(
        || Box::new(NfsLike::new(2, 3 << 30, Default::default())),
        "nfs",
    );
}

#[test]
fn ceph_batches_equal_sequential() {
    check_system(
        || Box::new(CephLike::new(3, 3 << 30, Default::default())),
        "ceph",
    );
}

#[test]
fn octopus_batches_equal_sequential() {
    check_system(|| Box::new(OctopusLike::new(2, Default::default())), "octopus");
}
