//! CRAQ apportioned-read integration (read-from-any-replica): clean
//! reads are served by the nearest live chain member, dirty hits confirm
//! with the tail, and killing the chain head mid-workload must neither
//! stop reads nor let survivors serve a stale payload.

use assise::fs::{FsError, Payload};
use assise::sim::{Cluster, ClusterConfig, DistFs};
use assise::util::SplitMix64;

fn encode(v: u64) -> Vec<u8> {
    v.to_le_bytes().to_vec()
}

fn decode(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

#[test]
fn head_kill_keeps_clean_reads_flowing() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
    let w = c.spawn_process(0, 0); // writer colocated with the chain head
    let fd = c.create(w, "/v").unwrap();
    c.pwrite(w, fd, 0, Payload::bytes(encode(1))).unwrap();
    c.fsync(w, fd).unwrap();
    c.digest_log(w).unwrap();

    let r1 = c.spawn_process(1, 0);
    let r2 = c.spawn_process(2, 0);
    c.set_now(r1, c.now(w) + 1_000_000);
    c.set_now(r2, c.now(w) + 1_000_000);
    let f1 = c.open(r1, "/v").unwrap();
    let f2 = c.open(r2, "/v").unwrap();
    assert_eq!(decode(&c.pread(r1, f1, 0, 8).unwrap().materialize()), 1);

    // another committed version, then the head dies mid-workload
    c.set_now(w, c.now(w).max(c.now(r1)).max(c.now(r2)));
    c.pwrite(w, fd, 0, Payload::bytes(encode(2))).unwrap();
    c.fsync(w, fd).unwrap();
    c.digest_log(w).unwrap();
    let t = c.now(w);
    c.kill_node(0, t).unwrap();

    // surviving replicas keep serving clean reads — and never version 1
    for (i, &(r, f)) in [(r1, f1), (r2, f2)].iter().enumerate() {
        c.set_now(r, t + (i as u64 + 1) * 2_000_000_000);
        let got = decode(&c.pread(r, f, 0, 8).unwrap().materialize());
        assert_eq!(got, 2, "survivor must serve the committed version, never a stale payload");
    }
    // the reads were served by the survivors themselves
    assert_eq!(c.reads_served_by[0], 0, "the dead head cannot have served");
    assert!(c.reads_served_by[1] >= 1 && c.reads_served_by[2] >= 1);
    // ops through the dead node's process fail; reads elsewhere flowed
    assert!(matches!(c.pread(w, fd, 0, 8), Err(FsError::Crashed)));
}

#[test]
fn reads_survive_rolling_replica_loss_until_none_left() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(4).replication(3));
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/v").unwrap();
    c.pwrite(w, fd, 0, Payload::bytes(encode(7))).unwrap();
    c.fsync(w, fd).unwrap();
    c.digest_log(w).unwrap();

    // reader OFF the chain [0, 1, 2]
    let r = c.spawn_process(3, 0);
    c.set_now(r, c.now(w) + 1_000_000);
    let f = c.open(r, "/v").unwrap();
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 7);

    // kill replicas one by one: reads keep working until the last dies
    let mut t = c.now(r);
    for dead in [1usize, 2, 0] {
        t += 2_000_000_000;
        c.kill_node(dead, t).unwrap();
        c.set_now(r, t + 1_500_000_000);
        let res = c.pread(r, f, 0, 8);
        if dead == 0 {
            // that was the last configured replica
            assert!(
                matches!(res, Err(FsError::ChainUnavailable(_))),
                "all replicas down must surface ChainUnavailable, got {res:?}"
            );
        } else {
            assert_eq!(decode(&res.unwrap().materialize()), 7, "after killing node {dead}");
        }
    }
}

/// One writer, readers on every node, random interleavings of writes,
/// fsyncs, digests, and reads. The CRAQ invariants under test: a read
/// never returns a version older than the last one whose digest
/// completed before the read was issued (clean reads are committed
/// reads), never one newer than the writer produced, per-reader
/// observations are monotonic, and the writer always reads its own
/// latest write.
#[test]
fn prop_reads_never_older_than_acked_fsync() {
    for seed in 0..10 {
        let mut rng = SplitMix64::new(9000 + seed);
        let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
        let w = c.spawn_process(0, 0);
        let fd = c.create(w, "/v").unwrap();
        c.pwrite(w, fd, 0, Payload::bytes(encode(1))).unwrap();
        c.fsync(w, fd).unwrap();
        c.digest_log(w).unwrap();

        let readers =
            [c.spawn_process(0, 0), c.spawn_process(1, 0), c.spawn_process(2, 0)];
        let mut rfds = Vec::new();
        for &r in readers.iter() {
            c.set_now(r, c.now(w));
            rfds.push(c.open(r, "/v").unwrap());
        }

        let mut latest = 1u64; // writer's last completed write
        let mut committed = 1u64; // last version whose digest completed
        let mut last_seen = [1u64; 3];
        for _ in 0..60 {
            match rng.below(4) {
                0 => {
                    latest += 1;
                    c.pwrite(w, fd, 0, Payload::bytes(encode(latest))).unwrap();
                }
                1 => {
                    c.fsync(w, fd).unwrap();
                }
                2 => {
                    c.fsync(w, fd).unwrap();
                    c.digest_log(w).unwrap();
                    committed = latest;
                }
                _ => {
                    let i = rng.below(3) as usize;
                    let r = readers[i];
                    // the read is issued at-or-after the digest completion
                    c.set_now(r, c.now(r).max(c.now(w)));
                    let got = decode(&c.pread(r, rfds[i], 0, 8).unwrap().materialize());
                    assert!(
                        got >= committed,
                        "seed {seed}: read version {got} older than committed {committed}"
                    );
                    assert!(
                        got <= latest,
                        "seed {seed}: read version {got} newer than written {latest}"
                    );
                    assert!(
                        got >= last_seen[i],
                        "seed {seed}: reader {i} went backwards: {got} < {}",
                        last_seen[i]
                    );
                    last_seen[i] = got;
                }
            }
        }
        assert!(c.craq.clean_reads + c.craq.dirty_redirects > 0);
        // the writer's own view is always its latest write
        let own = decode(&c.pread(w, fd, 0, 8).unwrap().materialize());
        assert_eq!(own, latest, "seed {seed}: writer must read its own write");
    }
}

/// The same CRAQ invariants with a 10× NVM straggler sitting in the
/// chain: the ranking demotes (never drops) the slow replica, remote
/// readers route around it, and no read weakens — not stale, not torn,
/// not backwards.
#[test]
fn prop_craq_invariants_hold_with_straggler_in_chain() {
    for seed in 0..5 {
        let mut rng = SplitMix64::new(9500 + seed);
        let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
        c.straggle_nvm(1, 10).unwrap();
        let w = c.spawn_process(0, 0);
        let fd = c.create(w, "/v").unwrap();
        c.pwrite(w, fd, 0, Payload::bytes(encode(1))).unwrap();
        c.fsync(w, fd).unwrap();
        c.digest_log(w).unwrap();

        let readers =
            [c.spawn_process(0, 0), c.spawn_process(1, 0), c.spawn_process(2, 0)];
        let mut rfds = Vec::new();
        for &r in readers.iter() {
            c.set_now(r, c.now(w));
            rfds.push(c.open(r, "/v").unwrap());
        }

        let mut latest = 1u64;
        let mut committed = 1u64;
        let mut last_seen = [1u64; 3];
        for _ in 0..60 {
            match rng.below(4) {
                0 => {
                    latest += 1;
                    c.pwrite(w, fd, 0, Payload::bytes(encode(latest))).unwrap();
                }
                1 => {
                    c.fsync(w, fd).unwrap();
                }
                2 => {
                    c.fsync(w, fd).unwrap();
                    c.digest_log(w).unwrap();
                    committed = latest;
                }
                _ => {
                    let i = rng.below(3) as usize;
                    let r = readers[i];
                    c.set_now(r, c.now(r).max(c.now(w)));
                    let got = decode(&c.pread(r, rfds[i], 0, 8).unwrap().materialize());
                    assert!(
                        got >= committed,
                        "seed {seed}: straggler chain served stale {got} < {committed}"
                    );
                    assert!(got <= latest, "seed {seed}: torn read {got} > {latest}");
                    assert!(got >= last_seen[i], "seed {seed}: reader {i} went backwards");
                    last_seen[i] = got;
                }
            }
        }
        assert!(c.craq.clean_reads + c.craq.dirty_redirects > 0);
        let own = decode(&c.pread(w, fd, 0, 8).unwrap().materialize());
        assert_eq!(own, latest, "seed {seed}: writer must read its own write");
    }
}

/// Non-colocated readers spread over the chain instead of funneling to
/// the head — the load-distribution half of apportioned reads.
#[test]
fn concurrent_readers_spread_over_non_head_replicas() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(6).replication(3));
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/big").unwrap();
    c.pwrite(w, fd, 0, Payload::zero(256 << 10)).unwrap();
    c.fsync(w, fd).unwrap();
    c.digest_log(w).unwrap();
    let t0 = c.now(w) + 1_000_000;
    // readers on nodes 3, 4, 5 (outside the chain [0, 1, 2]); tiny read
    // cache so every read hits a replica store
    for (i, node) in [3usize, 4, 5].iter().enumerate() {
        let r = c.spawn_process(*node, 0);
        c.set_now(r, t0 + i as u64 * 1_000);
        let f = c.open(r, "/big").unwrap();
        for k in 0..4u64 {
            let d = c.pread(r, f, k * (64 << 10), 64 << 10).unwrap();
            assert_eq!(d.len(), 64 << 10);
        }
    }
    assert_eq!(
        c.reads_served_by[0], 0,
        "head should serve no reads while non-head members are clean"
    );
    assert!(
        c.reads_served_by[1] > 0 && c.reads_served_by[2] > 0,
        "reads must spread over both non-head members: {:?}",
        c.reads_served_by
    );
}
