//! Multi-core namespace concurrency (`Cluster::submit_mc`), the
//! concurrent-namespace tentpole's semantic contract:
//!
//! - the seeded interleaved ring is state- and error-class-equivalent
//!   to a sequential per-thread reference — for every seed, because
//!   every scheduling decision comes from the seeded interleaver;
//! - epoch-snapshot reads never observe a half-applied digest: the
//!   store's apply seqlock always quiesces to an even epoch, and
//!   namespace reads go through the per-socket replica model;
//! - the ring-sample history feeding the adaptive window controller
//!   stays bounded at `ReplWindowStats::RING_SAMPLE_CAP`.

use std::mem::discriminant;

use assise::fs::{Fd, FsError, Payload};
use assise::metrics::ReplWindowStats;
use assise::sim::{Cluster, ClusterConfig, DistFs, FsOp};
use assise::util::SplitMix64;

/// A cluster with one process and `cores` disjoint per-core subtrees
/// `/t{c}` (each holding an open file `/t{c}/f`). Identical setups
/// allocate identical fds, so generated op streams transfer verbatim.
fn setup(cores: usize) -> (Cluster, usize, Vec<Fd>) {
    let mut c = Cluster::new(ClusterConfig::default());
    let pid = c.spawn_process(0, 0);
    let mut fds = Vec::new();
    for t in 0..cores {
        c.mkdir(pid, &format!("/t{t}")).unwrap();
        fds.push(c.create(pid, &format!("/t{t}/f")).unwrap());
    }
    (c, pid, fds)
}

/// Seeded op stream where op `i` belongs to core `i % cores` and
/// touches ONLY that core's subtree — so any interleaving must be
/// equivalent to replaying each core's ops in program order. The mix
/// deliberately includes error-producing ops (duplicate creates,
/// unlinks of absent files, stats of missing paths): error classes are
/// part of the contract.
fn gen_ops(seed: u64, cores: usize, per_core: usize, fds: &[Fd]) -> Vec<FsOp> {
    let mut rng = SplitMix64::new(seed);
    (0..cores * per_core)
        .map(|i| {
            let t = i % cores;
            match rng.below(8) {
                0 => FsOp::Pwrite {
                    fd: fds[t],
                    off: rng.below(1 << 14),
                    data: Payload::bytes(vec![t as u8; 64]),
                },
                1 => FsOp::Truncate { path: format!("/t{t}/f"), size: rng.below(1 << 14) },
                2 => FsOp::Readdir { path: format!("/t{t}") },
                3 => FsOp::Create { path: format!("/t{t}/g{}", rng.below(3)) },
                4 => FsOp::Unlink { path: format!("/t{t}/g{}", rng.below(3)) },
                5 => FsOp::Stat { path: format!("/t{t}/missing") },
                6 => FsOp::Pread { fd: fds[t], off: rng.below(1 << 14), len: 64 },
                _ => FsOp::Stat { path: format!("/t{t}/f") },
            }
        })
        .collect()
}

type OpClass = Result<(), std::mem::Discriminant<FsError>>;

fn class_of(r: Result<assise::sim::FsOut, FsError>) -> OpClass {
    r.map(|_| ()).map_err(|e| discriminant(&e))
}

/// API-observable namespace state: per subtree, the sorted listing and
/// each entry's size (mtime is virtual-time-dependent and excluded —
/// the contract is state equivalence, not timing equivalence).
fn observe(c: &mut Cluster, pid: usize, cores: usize) -> Vec<(String, Vec<(String, u64)>)> {
    (0..cores)
        .map(|t| {
            let dir = format!("/t{t}");
            let mut names = c.readdir(pid, &dir).unwrap();
            names.sort();
            let files = names
                .iter()
                .map(|n| (n.clone(), c.stat(pid, &format!("{dir}/{n}")).unwrap().size))
                .collect();
            (dir, files)
        })
        .collect()
}

#[test]
fn interleaved_matches_sequential_reference_over_seeds() {
    for cores in [2usize, 4, 8] {
        for seed in 0..6u64 {
            let (mut ca, pid_a, fds_a) = setup(cores);
            let (mut cb, pid_b, fds_b) = setup(cores);
            assert_eq!(fds_a, fds_b, "identical setups must allocate identical fds");
            let ops = gen_ops(seed, cores, 24, &fds_a);

            let inter: Vec<OpClass> = ca
                .submit_mc(pid_a, cores, seed, ops.clone())
                .into_iter()
                .map(|cq| class_of(cq.result))
                .collect();

            // sequential per-thread reference: each core's ops in
            // program order, one core after another
            let mut seq: Vec<Option<OpClass>> = vec![None; ops.len()];
            for core in 0..cores {
                for (i, op) in ops.iter().enumerate() {
                    if i % cores == core {
                        let cq = cb.submit(pid_b, vec![op.clone()]).remove(0);
                        seq[i] = Some(class_of(cq.result));
                    }
                }
            }
            let seq: Vec<OpClass> = seq.into_iter().map(|s| s.unwrap()).collect();

            assert_eq!(
                inter, seq,
                "cores={cores} seed={seed}: per-op error classes diverge"
            );
            assert_eq!(
                observe(&mut ca, pid_a, cores),
                observe(&mut cb, pid_b, cores),
                "cores={cores} seed={seed}: final namespace state diverges"
            );
        }
    }
}

#[test]
fn interleaved_ring_is_seed_deterministic() {
    let cores = 8;
    let (mut ca, pid_a, fds_a) = setup(cores);
    let (mut cb, pid_b, _fds_b) = setup(cores);
    let ops = gen_ops(99, cores, 32, &fds_a);
    let a: Vec<_> = ca
        .submit_mc(pid_a, cores, 7, ops.clone())
        .into_iter()
        .map(|cq| (class_of(cq.result), cq.latency))
        .collect();
    let b: Vec<_> = cb
        .submit_mc(pid_b, cores, 7, ops)
        .into_iter()
        .map(|cq| (class_of(cq.result), cq.latency))
        .collect();
    assert_eq!(a, b, "same seed must reproduce completions AND latencies exactly");
    assert_eq!(ca.now(pid_a), cb.now(pid_b), "virtual clocks must agree");
}

#[test]
fn snapshot_reads_never_observe_mid_apply() {
    let cores = 8;
    let (mut c, pid, _fds) = setup(cores);
    // seed the namespace into the SharedFS store, then interleave
    // stat-heavy rings with digests that reopen the apply seqlock
    c.digest_log(pid).unwrap();
    for r in 0..10u64 {
        let ops: Vec<FsOp> = (0..64usize)
            .map(|i| {
                let t = i % cores;
                if i % 8 == 7 {
                    FsOp::Truncate { path: format!("/t{t}/f"), size: (i as u64 % 4) * 512 }
                } else {
                    FsOp::Stat { path: format!("/t{t}/f") }
                }
            })
            .collect();
        for cq in c.submit_mc(pid, cores, r, ops) {
            cq.result.unwrap();
        }
        c.digest_log(pid).unwrap();
        // the apply seqlock must quiesce even: no reader can be left
        // inside (or observing) a half-applied digest
        for node in &c.nodes {
            for s in &node.sockets {
                assert!(!s.sharedfs.store.mid_apply(), "store left mid-apply");
                assert_eq!(s.sharedfs.store.epoch() % 2, 0, "odd epoch after quiesce");
            }
        }
    }
    let ns = &c.ns_stats;
    assert!(
        ns.replica_hits + ns.replica_refreshes > 0,
        "namespace reads must go through the per-socket replica model"
    );
    assert!(
        ns.replica_refreshes > 0,
        "digest epoch bumps must force replica refreshes"
    );
    assert!(ns.combined_batches > 0, "mutations must flat-combine");
}

#[test]
fn ring_history_is_bounded() {
    // satellite: ReplWindowStats::rings must not grow one sample per
    // ring forever on a long-lived cluster
    let mut cfg = ClusterConfig::default().log_capacity(256 << 10);
    cfg.digest_threshold = 0.001; // every ring crosses the digest bar
    let mut c = Cluster::new(cfg);
    let pid = c.spawn_process(0, 0);
    let fd = c.create(pid, "/f").unwrap();
    let rings = ReplWindowStats::RING_SAMPLE_CAP + 40;
    for k in 0..rings as u64 {
        let ops = vec![
            FsOp::Pwrite { fd, off: k * 1024, data: Payload::zero(1024) },
            FsOp::Fsync { fd },
        ];
        for cq in c.submit(pid, ops) {
            cq.result.unwrap();
        }
    }
    assert!(
        c.repl_window_stats.windows >= rings as u64,
        "every ring should have issued at least one replication window"
    );
    assert_eq!(
        c.repl_window_stats.rings.len(),
        ReplWindowStats::RING_SAMPLE_CAP,
        "ring-sample history must stay bounded"
    );
}
