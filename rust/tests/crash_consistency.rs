//! Crash-consistency tests (CrashMonkey-style, paper §5): inject
//! failures at every interesting point and verify CC-NVM's guarantees:
//!
//! - **prefix semantics**: survivors observe exactly a prefix of the
//!   fsync'd write history — in order, no holes;
//! - **local recovery completeness**: a process restart on the same node
//!   recovers ALL completed writes, replicated or not, in both modes;
//! - **idempotent digest**: replaying digests after a crash converges.

use assise::fs::Payload;
use assise::sim::{Cluster, ClusterConfig, CrashMode, DistFs};

fn cluster(mode: CrashMode) -> Cluster {
    Cluster::new(ClusterConfig::default().nodes(2).mode(mode))
}

#[test]
fn prefix_semantics_on_failover() {
    // write v1..v5; fsync after v3; kill the node. The backup must see
    // exactly v1..v3 (the replicated prefix), never v4/v5, never a hole.
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    for i in 1..=3u8 {
        c.write(p, fd, Payload::bytes(vec![i; 100])).unwrap();
    }
    c.fsync(p, fd).unwrap();
    for i in 4..=5u8 {
        c.write(p, fd, Payload::bytes(vec![i; 100])).unwrap();
    }
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(p, 1, 0, t).unwrap();
    assert_eq!(report.lost_entries, 2);
    let fd2 = c.open(np, "/f").unwrap();
    let st = c.stat(np, "/f").unwrap();
    assert_eq!(st.size, 300, "exactly the fsync'd prefix");
    let data = c.pread(np, fd2, 0, 300).unwrap().materialize();
    for i in 1..=3u8 {
        assert_eq!(&data[(i as usize - 1) * 100..i as usize * 100], &vec![i; 100][..]);
    }
}

#[test]
fn no_holes_in_recovered_prefix() {
    // interleave writes to two files with one fsync point; after
    // failover both files must reflect the same cut
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    c.mkdir(p, "/d").unwrap();
    let fa = c.create(p, "/d/a").unwrap();
    let fb = c.create(p, "/d/b").unwrap();
    c.write(p, fa, Payload::bytes(b"a1".to_vec())).unwrap();
    c.write(p, fb, Payload::bytes(b"b1".to_vec())).unwrap();
    c.fsync(p, fa).unwrap(); // fsync replicates the whole log prefix
    c.write(p, fa, Payload::bytes(b"a2".to_vec())).unwrap();
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    let (np, _) = c.failover_process(p, 1, 0, t).unwrap();
    let fa2 = c.open(np, "/d/a").unwrap();
    let fb2 = c.open(np, "/d/b").unwrap();
    // the fsync covers BOTH files' earlier writes (log is totally ordered)
    assert_eq!(c.pread(np, fa2, 0, 2).unwrap().materialize(), b"a1");
    assert_eq!(c.pread(np, fb2, 0, 2).unwrap().materialize(), b"b1");
    assert_eq!(c.stat(np, "/d/a").unwrap().size, 2, "a2 must be lost");
}

#[test]
fn local_restart_recovers_unreplicated_writes_pessimistic() {
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"never-fsynced".to_vec())).unwrap();
    let t = c.now(p);
    c.kill_process(p).unwrap();
    c.restart_process(p, t).unwrap();
    let fd2 = c.open(p, "/f").unwrap();
    assert_eq!(c.pread(p, fd2, 0, 13).unwrap().materialize(), b"never-fsynced");
}

#[test]
fn local_restart_recovers_optimistic_mode_too() {
    // §3.4: "recovering all completed writes (even in optimistic mode)"
    let mut c = cluster(CrashMode::Optimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"optimistic".to_vec())).unwrap();
    c.fsync(p, fd).unwrap(); // no-op in this mode
    let t = c.now(p);
    c.kill_process(p).unwrap();
    c.restart_process(p, t).unwrap();
    let fd2 = c.open(p, "/f").unwrap();
    assert_eq!(c.pread(p, fd2, 0, 10).unwrap().materialize(), b"optimistic");
}

#[test]
fn optimistic_failover_loses_uncoalesced_suffix_only() {
    let mut c = cluster(CrashMode::Optimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(vec![1; 64])).unwrap();
    c.dsync(p, fd).unwrap(); // explicit persistence point
    c.write(p, fd, Payload::bytes(vec![2; 64])).unwrap();
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(p, 1, 0, t).unwrap();
    assert_eq!(report.lost_entries, 1);
    assert_eq!(c.stat(np, "/f").unwrap().size, 64);
}

#[test]
fn crash_mid_digest_replay_converges() {
    // the digest watermark protects against double-apply; simulate a
    // crash between digesting on replica A and replica B, then re-digest
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"payload".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    let before0 = c.nodes[0].sockets[0].sharedfs.store.clone();
    // replay the same digest (recovery path calls are idempotent)
    c.digest_log(p).unwrap();
    c.digest_log(p).unwrap();
    assert!(c.nodes[0].sockets[0].sharedfs.store.content_eq(&before0));
    assert!(c.nodes[0].sockets[0].sharedfs.store.content_eq(&c.nodes[1].sockets[0].sharedfs.store));
}

#[test]
fn rename_durability_across_failover() {
    // the Maildir pattern: write tmp, fsync, rename, fsync — after
    // fail-over the message must be at the destination, never both/none
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    c.mkdir(p, "/q").unwrap();
    c.mkdir(p, "/mbox").unwrap();
    let fd = c.create(p, "/q/tmp").unwrap();
    c.write(p, fd, Payload::bytes(b"mail body".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.rename(p, "/q/tmp", "/mbox/msg").unwrap();
    c.fsync(p, fd).unwrap();
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    let (np, _) = c.failover_process(p, 1, 0, t).unwrap();
    assert!(c.stat(np, "/mbox/msg").is_ok());
    assert!(c.stat(np, "/q/tmp").is_err());
    let fd2 = c.open(np, "/mbox/msg").unwrap();
    assert_eq!(c.pread(np, fd2, 0, 9).unwrap().materialize(), b"mail body");
}

#[test]
fn epoch_invalidation_prevents_stale_reads() {
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"OLD".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    // node 1 dies; the survivor overwrites
    let t = c.now(p);
    c.kill_node(1, t).unwrap();
    c.pwrite(p, fd, 0, Payload::bytes(b"NEW".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    // node 1 rejoins and a local reader appears
    let t2 = c.now(p);
    c.recover_node(1, t2).unwrap();
    let p2 = c.spawn_process(1, 0);
    c.set_now(p2, t2 + 1_000_000);
    let fd2 = c.open(p2, "/f").unwrap();
    assert_eq!(
        c.pread(p2, fd2, 0, 3).unwrap().materialize(),
        b"NEW",
        "stale NVM content must be invalidated by epoch recovery"
    );
}

#[test]
fn cascading_failure_to_reserve_replica() {
    // §3.5: when all cache replicas die, processes fail over to the
    // reserve replica (which then serves from its NVM reserve tier)
    let mut c = Cluster::new(
        ClusterConfig::default().nodes(3).replication(2).reserves(1),
    );
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"survives cascade".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    c.kill_node(1, t + 1_000).unwrap();
    // fail over to the reserve replica (node 2)
    let (np, _) = c.failover_process(p, 2, 0, t + 1_000).unwrap();
    let fd2 = c.open(np, "/f").unwrap();
    assert_eq!(c.pread(np, fd2, 0, 16).unwrap().materialize(), b"survives cascade");
}

#[test]
fn os_failover_recovers_locally_without_data_loss() {
    // §5.4 "OS fail-over": VM snapshot boot + SharedFS recovery from NVM;
    // everything in the NVM log survives, volatile state rebuilds
    let mut c = cluster(CrashMode::Pessimistic);
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"pre-reboot".to_vec())).unwrap();
    // not fsynced: still recovered (NVM log survives an OS reboot)
    let t = c.now(p);
    let (ready, report) = c.os_failover(0, t).unwrap();
    assert_eq!(report.lost_entries, 0);
    // boot dominated by the 1.66 s snapshot start (paper: 1.66 + 0.23 s)
    assert!(ready - t >= 1_660_000_000, "{}", ready - t);
    assert!(ready - t < 3_000_000_000, "{}", ready - t);
    // restart the process locally and read everything back
    c.restart_process(p, ready).unwrap();
    let fd2 = c.open(p, "/f").unwrap();
    assert_eq!(c.pread(p, fd2, 0, 10).unwrap().materialize(), b"pre-reboot");
}
