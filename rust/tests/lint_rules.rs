//! assise-lint's own tests: lexer unit tests, fixture-driven rule tests,
//! an end-to-end run over the seeded fixture tree (which must fail), and
//! a dogfood run over this repository (which must be clean).

#![allow(dead_code)] // the #[path]-included lint core exceeds what any one test uses

#[path = "../../tools/lint/core/mod.rs"]
mod lintcore;

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::lintcore::lexer::{self, Kind};
use crate::lintcore::rules::{determinism, fault_routing, panic_ratchet, san_funnel};
use crate::lintcore::{Allowlist, Baseline, Diag, SourceFile};

fn fixture(name: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tools/lint/fixtures")
        .join(name);
    match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(e) => panic!("read {}: {e}", path.display()),
    }
}

fn tree_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tools/lint/fixtures/tree")
}

/// Run the per-file rules the same way the real walker does, with an
/// empty allowlist.
fn check_file(rel: &str, src: &str) -> Vec<Diag> {
    let file = SourceFile::load(rel, src, &Allowlist::new());
    let mut diags = Vec::new();
    fault_routing::check(&file, &mut diags);
    determinism::check(&file, &mut diags);
    san_funnel::check(&file, &mut diags);
    diags
}

fn counts_of(unwrap: u64, index: u64) -> panic_ratchet::Counts {
    let mut c = panic_ratchet::Counts::new();
    c.insert("unwrap", unwrap);
    c.insert("index", index);
    c
}

// ================================================================ lexer

#[test]
fn comments_and_strings_yield_no_rule_tokens() {
    let src = "// fabric.rpc( in a line comment\n\
               /* outer /* nested fabric.rpc( */ closed */\n\
               let s = \"fabric.rpc(\\\" escaped\";\n";
    let toks = lexer::lex(src);
    assert!(
        !toks.iter().any(|t| t.kind == Kind::Ident && t.text == "fabric"),
        "{toks:?}"
    );
    let strs: Vec<&lexer::Token> = toks.iter().filter(|t| t.kind == Kind::Str).collect();
    assert_eq!(strs.len(), 1);
    assert_eq!(strs[0].text, "fabric.rpc(\\\" escaped");
}

#[test]
fn raw_strings_swallow_quotes_and_calls() {
    let src = "let r = r#\"quote \" and unwrap() inside\"#;\n\
               let b = br\"bytes\";\n\
               let n = r##\"uses \"# inside\"##;\n";
    let toks = lexer::lex(src);
    assert!(!toks.iter().any(|t| t.kind == Kind::Ident && t.text == "unwrap"));
    let strs: Vec<String> = toks
        .iter()
        .filter(|t| t.kind == Kind::Str)
        .map(|t| t.text.clone())
        .collect();
    assert_eq!(strs, ["quote \" and unwrap() inside", "bytes", "uses \"# inside"]);
}

#[test]
fn char_literals_are_not_lifetimes() {
    let src = "fn f<'a>(x: &'a str) -> char { let c = 'a'; let n = '\\n'; let b = b'x'; c }";
    let toks = lexer::lex(src);
    let lifetimes: Vec<&str> = toks
        .iter()
        .filter(|t| t.kind == Kind::Lifetime)
        .map(|t| t.text.as_str())
        .collect();
    assert_eq!(lifetimes, ["a", "a"]);
    let chars: Vec<&str> = toks
        .iter()
        .filter(|t| t.kind == Kind::Char)
        .map(|t| t.text.as_str())
        .collect();
    assert_eq!(chars, ["a", "\\n", "x"]);
}

#[test]
fn token_lines_survive_multiline_literals() {
    let src = "let a = \"line\nbreak\";\nlet t0 = 7;";
    let toks = lexer::lex(src);
    let t0 = toks.iter().find(|t| t.text == "t0").unwrap();
    assert_eq!(t0.line, 3);
    let s = toks.iter().find(|t| t.kind == Kind::Str).unwrap();
    assert_eq!(s.line, 1);
}

#[test]
fn cfg_test_regions_are_tracked() {
    let src = "fn prod(x: Option<u8>) { x.unwrap(); }\n\n\
               #[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
    let toks = lexer::lex(src);
    let regions = lexer::test_regions(&toks);
    assert_eq!(regions.len(), 1, "{regions:?}");
    let unwraps: Vec<usize> = toks
        .iter()
        .enumerate()
        .filter(|(_, t)| t.text == "unwrap")
        .map(|(i, _)| i)
        .collect();
    assert_eq!(unwraps.len(), 2);
    assert!(!lexer::in_regions(&regions, unwraps[0]), "prod unwrap is outside");
    assert!(lexer::in_regions(&regions, unwraps[1]), "test unwrap is inside");
}

// =============================================================== config

#[test]
fn config_subset_parses_sections_ints_and_arrays() {
    let doc = lintcore::config::parse(
        "# comment\n[module.sim]\nunwrap = 3 # trailing\n\n\
         [fault-routing]\nallow = [\n  \"rust/src/hw/\",\n  \"rust/src/baselines/\",\n]\n",
    )
    .unwrap();
    assert_eq!(doc["module.sim"]["unwrap"], lintcore::config::Value::Int(3));
    assert_eq!(
        doc["fault-routing"]["allow"],
        lintcore::config::Value::List(vec![
            "rust/src/hw/".to_string(),
            "rust/src/baselines/".to_string()
        ])
    );
}

#[test]
fn config_rejects_constructs_outside_the_subset() {
    let (line, _) = lintcore::config::parse("[s]\nkey value\n").unwrap_err();
    assert_eq!(line, 2);
}

#[test]
fn allowlist_and_baseline_load_from_parsed_docs() {
    let doc = lintcore::config::parse(
        "[determinism]\nallow = [\"rust/src/bench/\"]\n[module.sim]\nunwrap = 7\n",
    )
    .unwrap();
    let allow = lintcore::load_allowlist(&doc);
    assert_eq!(allow["determinism"], vec!["rust/src/bench/".to_string()]);
    let base = lintcore::load_baseline(&doc);
    assert_eq!(base["sim"]["unwrap"], 7);
}

// ======================================================== fault-routing

#[test]
fn fault_routing_flags_raw_fabric_and_chain_ship() {
    let src = fixture("fault_routing_violation.rs");
    let diags = check_file("rust/src/cluster/demo.rs", &src);
    assert_eq!(diags.len(), 2, "{diags:?}");
    assert!(diags.iter().all(|d| d.rule == "fault-routing"));

    // under sim/ the chain_ship_cost call is legitimate; fabric.rpc is not
    let diags = check_file("rust/src/sim/demo.rs", &src);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].rule, "fault-routing");
}

#[test]
fn fault_routing_ignores_comments_and_strings() {
    let diags = check_file("rust/src/cluster/demo.rs", &fixture("fault_routing_clean.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

// =========================================================== san-funnel

#[test]
fn san_funnel_flags_direct_funnel_state_mutation() {
    let src = fixture("san_funnel_violation.rs");
    let diags = check_file("rust/src/cluster/demo.rs", &src);
    let hits: Vec<&Diag> = diags.iter().filter(|d| d.rule == "san-funnel").collect();
    assert_eq!(hits.len(), 4, "versions.bump, leases.acquire, and both cursor advances: {diags:?}");
}

#[test]
fn san_funnel_skips_test_regions_comments_and_strings() {
    // the violation fixture's #[cfg(test)] poke must be among the 4 above,
    // and the clean fixture (funnel calls + mentions in strings) is silent
    let diags = check_file("rust/src/cluster/demo.rs", &fixture("san_funnel_clean.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn san_funnel_is_silent_under_the_owning_modules() {
    let src = fixture("san_funnel_violation.rs");
    let mut allow = Allowlist::new();
    allow.insert("san-funnel".to_string(), vec!["rust/src/sim/".to_string()]);
    let file = SourceFile::load("rust/src/sim/demo.rs", &src, &allow);
    let mut diags = Vec::new();
    san_funnel::check(&file, &mut diags);
    assert!(diags.is_empty(), "{diags:?}");
}

// ========================================================== determinism

#[test]
fn determinism_flags_wall_clocks_and_threads() {
    let diags = check_file("rust/src/sim/demo.rs", &fixture("determinism_violation.rs"));
    assert!(diags.len() >= 5, "{diags:?}");
    assert!(diags.iter().all(|d| d.rule == "determinism"), "{diags:?}");
}

#[test]
fn determinism_ignores_comments_and_strings() {
    let diags = check_file("rust/src/sim/demo.rs", &fixture("determinism_clean.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn nanos_sub_fires_only_under_sim_and_hw() {
    let src = fixture("nanos_sub_violation.rs");
    let sim = check_file("rust/src/sim/demo.rs", &src);
    assert_eq!(sim.iter().filter(|d| d.rule == "nanos-sub").count(), 2, "{sim:?}");
    let bench = check_file("rust/src/bench/demo.rs", &src);
    assert!(bench.is_empty(), "{bench:?}");
}

#[test]
fn nanos_sub_accepts_saturating_waived_and_plain_arithmetic() {
    let diags = check_file("rust/src/sim/demo.rs", &fixture("nanos_sub_clean.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn waiver_covers_its_own_line_and_the_next() {
    let src = "fn f(now: u64, sent_at: u64) -> u64 {\n\
               // assise-lint: allow(nanos-sub) — safe\n\
               now - sent_at\n}\n";
    let diags = check_file("rust/src/sim/demo.rs", src);
    assert!(diags.is_empty(), "{diags:?}");

    let unrelated = "fn f(now: u64, sent_at: u64) -> u64 {\n\
                     // assise-lint: allow(fault-routing) — wrong rule\n\
                     now - sent_at\n}\n";
    let diags = check_file("rust/src/sim/demo.rs", unrelated);
    assert_eq!(diags.len(), 1, "a waiver for a different rule must not suppress");
}

#[test]
fn allowlist_silences_a_rule_by_path_prefix() {
    let mut allow = Allowlist::new();
    allow.insert("nanos-sub".to_string(), vec!["rust/src/sim/".to_string()]);
    let src = fixture("nanos_sub_violation.rs");
    let file = SourceFile::load("rust/src/sim/demo.rs", &src, &allow);
    let mut diags = Vec::new();
    determinism::check(&file, &mut diags);
    assert!(diags.is_empty(), "{diags:?}");
}

// ======================================================== panic-ratchet

#[test]
fn panic_counter_matches_fixture_inventory() {
    let toks = lexer::lex(&fixture("panic_sites.rs"));
    let c = panic_ratchet::count_tokens(&toks);
    let want = [
        ("unwrap", 2),
        ("expect", 1),
        ("panic", 1),
        ("unreachable", 1),
        ("todo", 1),
        ("index", 1),
    ];
    for (cat, n) in want {
        assert_eq!(c.get(cat), Some(&n), "category {cat}: {c:?}");
    }
}

#[test]
fn module_key_is_first_component_under_src() {
    assert_eq!(panic_ratchet::module_of("rust/src/sim/assise.rs").as_deref(), Some("sim"));
    assert_eq!(panic_ratchet::module_of("rust/src/lib.rs").as_deref(), Some("lib"));
    assert_eq!(panic_ratchet::module_of("rust/tests/integration.rs"), None);
}

#[test]
fn ratchet_blocks_increases_and_suggests_decreases() {
    let current: BTreeMap<String, panic_ratchet::Counts> =
        [("sim".to_string(), counts_of(3, 0))].into_iter().collect();

    let mut over: Baseline = BTreeMap::new();
    over.insert("sim".to_string(), [("unwrap".to_string(), 2)].into_iter().collect());
    let mut diags = Vec::new();
    let sugg = panic_ratchet::check_modules(&current, &over, &mut diags);
    assert_eq!(diags.len(), 1, "3 unwraps over a ceiling of 2 is a regression: {diags:?}");
    assert!(sugg.is_empty(), "{sugg:?}");

    let mut under: Baseline = BTreeMap::new();
    under.insert("sim".to_string(), [("unwrap".to_string(), 5)].into_iter().collect());
    let mut diags = Vec::new();
    let sugg = panic_ratchet::check_modules(&current, &under, &mut diags);
    assert!(diags.is_empty(), "below the ceiling is not a violation: {diags:?}");
    assert_eq!(sugg.len(), 1, "ratchet-down suggestion expected: {sugg:?}");
}

#[test]
fn stale_baseline_module_is_flagged_for_rewrite() {
    let current: BTreeMap<String, panic_ratchet::Counts> = BTreeMap::new();
    let mut base: Baseline = BTreeMap::new();
    base.insert("gone".to_string(), BTreeMap::new());
    let mut diags = Vec::new();
    let sugg = panic_ratchet::check_modules(&current, &base, &mut diags);
    assert!(diags.is_empty(), "{diags:?}");
    assert!(sugg.iter().any(|s| s.contains("`gone`")), "{sugg:?}");
}

#[test]
fn baseline_render_roundtrips_through_the_parser() {
    let mut counts = BTreeMap::new();
    counts.insert("sim".to_string(), counts_of(3, 1));
    let rendered = panic_ratchet::render_baseline(&counts);
    let doc = lintcore::config::parse(&rendered).unwrap();
    let base = lintcore::load_baseline(&doc);
    assert_eq!(base["sim"]["unwrap"], 3);
    assert_eq!(base["sim"]["index"], 1);
    assert_eq!(base["sim"]["todo"], 0);
}

// =========================================================== end to end

#[test]
fn seeded_tree_trips_every_rule() {
    let outcome = lintcore::run(&tree_root(), &Allowlist::new(), &Baseline::new()).unwrap();
    let rules: Vec<&str> = outcome.diags.iter().map(|d| d.rule).collect();
    for rule in [
        "fault-routing",
        "determinism",
        "nanos-sub",
        "panic-ratchet",
        "registration",
        "san-funnel",
    ] {
        assert!(rules.contains(&rule), "seeded tree must trip `{rule}`, got {rules:?}");
    }
}

#[test]
fn cli_exits_nonzero_on_seeded_tree() {
    let code = lintcore::run_cli(&["--root".to_string(), tree_root().display().to_string()]);
    assert_eq!(code, 1, "seeded violations must exit 1");
}

#[test]
fn cli_rejects_unknown_arguments() {
    assert_eq!(lintcore::run_cli(&["--bogus".to_string()]), 2);
}

#[test]
fn repo_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let code = lintcore::run_cli(&["--root".to_string(), root.display().to_string()]);
    assert_eq!(code, 0, "the committed tree must be assise-lint clean");
}
