//! Cross-system integration: the same workload driven through every
//! `DistFs` implementation must produce identical *contents* (the
//! baselines differ in cost, never in correctness), plus end-to-end
//! three-layer checks through the PJRT runtime.

use assise::baselines::{CephLike, NfsLike, OctopusLike};
use assise::fs::Payload;
use assise::sim::{Cluster, ClusterConfig, DistFs};
use assise::util::SplitMix64;

fn all_systems() -> Vec<Box<dyn DistFs>> {
    vec![
        Box::new(Cluster::new(ClusterConfig::default().nodes(3))),
        Box::new(CephLike::new(3, 1 << 30, Default::default())),
        Box::new(NfsLike::new(3, 1 << 30, Default::default())),
        Box::new(OctopusLike::new(3, Default::default())),
    ]
}

#[test]
fn same_oplog_same_contents_everywhere() {
    let mut outputs = Vec::new();
    for mut fs in all_systems() {
        let pid = fs.spawn_process(0, 0);
        fs.mkdir(pid, "/w").unwrap();
        let mut rng = SplitMix64::new(7);
        let mut digest = Vec::new();
        for i in 0..20u64 {
            let path = format!("/w/f{}", i % 5);
            let fd = match fs.open(pid, &path) {
                Ok(fd) => fd,
                Err(_) => fs.create(pid, &path).unwrap(),
            };
            let off = rng.below(1024);
            let data = Payload::synthetic(i, 64 + rng.below(512));
            fs.pwrite(pid, fd, off, data).unwrap();
            fs.fsync(pid, fd).unwrap();
            let st = fs.stat(pid, &path).unwrap();
            let back = fs.pread(pid, fd, 0, st.size).unwrap().materialize();
            digest.push((path.clone(), back));
            fs.close(pid, fd).unwrap();
        }
        outputs.push((fs.name(), digest));
    }
    let (ref_name, ref_digest) = &outputs[0];
    for (name, digest) in &outputs[1..] {
        assert_eq!(digest, ref_digest, "{name} diverged from {ref_name}");
    }
}

#[test]
fn latency_ordering_small_sync_writes() {
    // the paper's core latency claim, as an invariant:
    // assise < octopus < nfs < ceph for small synchronous writes
    let mut lat = std::collections::HashMap::new();
    for mut fs in all_systems() {
        let pid = fs.spawn_process(0, 0);
        let fd = fs.create(pid, "/f").unwrap();
        let mut total = 0u64;
        for i in 0..50u64 {
            fs.write(pid, fd, Payload::synthetic(i, 128)).unwrap();
            total += fs.last_latency(pid);
            fs.fsync(pid, fd).unwrap();
            total += fs.last_latency(pid);
        }
        lat.insert(fs.name().to_string(), total / 50);
    }
    assert!(lat["assise"] < lat["octopus"], "{lat:?}");
    assert!(lat["octopus"] < lat["nfs"], "{lat:?}");
    assert!(lat["nfs"] < lat["ceph"], "{lat:?}");
}

#[test]
fn three_layer_digest_verification_end_to_end() {
    // L3 write path -> digest -> L1 checksum kernel through PJRT
    if !assise::runtime::artifacts_dir().join("checksum.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = ClusterConfig::default().nodes(2);
    cfg.verify_digests = true;
    let mut c = Cluster::new(cfg);
    c.verifier = Some(assise::runtime::ChecksumExec::load().unwrap());
    let pid = c.spawn_process(0, 0);
    let fd = c.create(pid, "/verified").unwrap();
    for i in 0..8u64 {
        c.write(pid, fd, Payload::synthetic(i, 4096)).unwrap();
    }
    c.fsync(pid, fd).unwrap();
    c.digest_log(pid).unwrap(); // runs the checksum kernel on the batch
    assert!(c.nodes[1].sockets[0].sharedfs.store.exists("/verified"));
    let data = c.pread(pid, fd, 0, 8 * 4096).unwrap();
    assert_eq!(data.len(), 8 * 4096);
}

#[test]
fn sort_pipeline_kernel_vs_reference_same_output() {
    if !assise::runtime::artifacts_dir().join("partition.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use assise::workloads::sort::SortJob;
    let exec = assise::runtime::PartitionExec::load().unwrap();

    let run = |use_kernel: bool| {
        let mut c = Cluster::new(ClusterConfig::default().nodes(2).replication(1));
        let workers: Vec<_> = (0..4).map(|w| c.spawn_process(w % 2, 0)).collect();
        let job = SortJob { workers, records_per_worker: 800, use_kernel, batched: false };
        job.run(&mut c, if use_kernel { Some(&exec) } else { None }).unwrap()
    };
    let (_, count_kernel) = run(true);
    let (_, count_ref) = run(false);
    assert_eq!(count_kernel, 3200);
    assert_eq!(count_kernel, count_ref);
}

#[test]
fn dynamic_log_resize_two_phase_commit() {
    use assise::oplog::{ResizeOutcome, ResizePolicy};
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    let pid = c.spawn_process(0, 0);
    let policy = ResizePolicy::default();
    let old = c.procs[pid].log.capacity();
    let grown = policy.grow(old);
    match c.resize_log(pid, grown) {
        ResizeOutcome::Committed { new_size, completed_at } => {
            assert_eq!(new_size, grown);
            assert!(completed_at > 0, "2PC must cost RPC round trips");
            assert_eq!(c.procs[pid].log.capacity(), grown);
        }
        o => panic!("expected commit, got {o:?}"),
    }
    // writes keep flowing after the resize
    let fd = c.create(pid, "/after-resize").unwrap();
    c.write(pid, fd, Payload::bytes(vec![1u8; 4096])).unwrap();
    c.fsync(pid, fd).unwrap();
}

#[test]
fn log_resize_aborts_on_replica_nvm_pressure() {
    use assise::oplog::ResizeOutcome;
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    let pid = c.spawn_process(0, 0);
    // exhaust replica 1's NVM so its PREPARE vote denies
    let avail = c.nodes[1].sockets[0].nvm.available();
    assert!(c.nodes[1].sockets[0].nvm.alloc(avail));
    let old = c.procs[pid].log.capacity();
    match c.resize_log(pid, old * 2) {
        ResizeOutcome::Aborted { denier, .. } => {
            assert_eq!(denier, 1);
            assert_eq!(c.procs[pid].log.capacity(), old, "abort keeps the old size");
        }
        o => panic!("expected abort, got {o:?}"),
    }
}
