//! assise-san detection tests: every violation class the sanitizer
//! claims to catch gets a planted bug asserting the right checker
//! fires, plus the two contracts that make the sanitizer usable:
//!
//! - `SanMode::Off` is byte-identical: same seed, same virtual-time
//!   trace, zero events, zero allocations observable through stats;
//! - `SanMode::Full` over real (correct) workloads — including kills,
//!   fail-over, digest, and multi-core rings — reports ZERO violations.
//!
//! The planted-bug tests drive `SanState` directly through the same
//! public API the funnels use: the simulator's own paths are correct
//! (that is what the clean-workload tests pin), so the only way to
//! plant a lease bypass or a premature ack is to speak the funnel
//! protocol with the offending step omitted.

use assise::fs::Payload;
use assise::replication::ChainId;
use assise::sim::san::{explore, ExploreConfig, SanState, SanViolationKind};
use assise::sim::{Cluster, ClusterConfig, DistFs, FsOp, SanMode};

/// Planted-bug tests build reports to inspect; under `ASSISE_SAN`
/// strict arming the first violation asserts instead. Skip them there
/// (the CI smoke job runs this binary without the variable).
fn strict_env() -> bool {
    std::env::var_os("ASSISE_SAN").is_some()
}

// ======================================================== planted bugs

#[test]
fn lease_bypass_write_is_a_race() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.register_proc(1, 1);
    // proc 0 writes under a lease; proc 1 writes the same object with
    // no lease at all — nothing orders the two
    s.lease_acquire(0, "/d");
    let first = s.write_access(0, "/d/f");
    let second = s.write_access(1, "/d/f");
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::Race), 1, "{}", report.render());
    let v = report.violations.first().expect("one race");
    assert_eq!((v.first_op, v.second_op), (first, second));
    assert_eq!(v.object, "/d/f");
}

#[test]
fn leased_handoff_is_not_a_race() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.register_proc(1, 1);
    // proper handoff: write under lease, lease moves, next holder
    // writes — the lease edge orders the accesses
    s.lease_acquire(0, "/d");
    s.write_access(0, "/d/f");
    s.lease_release(0, "/d");
    s.lease_acquire(1, "/d");
    s.write_access(1, "/d/f");
    let report = s.report();
    assert!(report.is_clean(), "{}", report.render());
}

#[test]
fn subtree_lease_covers_descendant_objects() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.register_proc(1, 1);
    // a lease on /a is a lease on /a/b/c (hierarchical units); two
    // racing readers are never a violation either way
    s.lease_acquire(0, "/a");
    s.write_access(0, "/a/b/c");
    s.read_access(1, "/a/b/c");
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::Race), 1, "bypass read races the covered write");
    let mut s2 = SanState::new(SanMode::Full);
    s2.register_proc(0, 0);
    s2.register_proc(1, 1);
    s2.read_access(0, "/a/b/c");
    s2.read_access(1, "/a/b/c");
    assert!(s2.report().is_clean(), "read/read never races");
}

#[test]
fn ack_before_durable_is_caught() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.local_persist(0, 5);
    // the chain acks seq 5 claiming node 1 holds it — but no durable
    // note for node 1 ever arrived
    s.chain_ack(0, ChainId(0), 5, &[1], 0);
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::AckBeforeDurable), 1, "{}", report.render());
    assert_eq!(report.violations.first().map(|v| v.first_op), Some(5));
}

#[test]
fn durable_then_ack_is_clean_and_prefix_closed() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.local_persist(0, 7);
    s.replica_durable(1, 0, ChainId(0), 7);
    // watermark semantics: durability at 7 covers every ack <= 7
    s.chain_ack(0, ChainId(0), 3, &[1], 0);
    s.chain_ack(0, ChainId(0), 7, &[1], 0);
    // local-only chains (no remote members) are exempt by configuration
    s.chain_ack(0, ChainId(1), 9, &[], 0);
    assert!(s.report().is_clean(), "{}", s.report().render());
}

#[test]
fn retired_member_copy_never_satisfies_an_ack() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.local_persist(0, 3);
    s.replica_durable(1, 0, ChainId(0), 3);
    // live migration retires node 1 from the chain: its copy is stale
    // capital, and an ack leaning on it is a violation
    s.replica_retired(1, ChainId(0));
    s.chain_ack(0, ChainId(0), 3, &[1], 0);
    assert_eq!(s.report().count(SanViolationKind::AckBeforeDurable), 1);
    // a later durable write re-validates the copy
    s.local_persist(0, 4);
    s.replica_durable(1, 0, ChainId(0), 4);
    s.chain_ack(0, ChainId(0), 4, &[1], 0);
    assert_eq!(s.report().count(SanViolationKind::AckBeforeDurable), 1, "no new fault");
}

#[test]
fn crash_point_losing_every_copy_is_caught() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    s.local_persist(0, 2);
    s.replica_durable(1, 0, ChainId(0), 2);
    s.chain_ack(0, ChainId(0), 2, &[1], 0);
    // killing one copy is survivable (that is what the ack bought)...
    s.node_down(1);
    assert!(s.report().is_clean(), "{}", s.report().render());
    // ...killing BOTH copies orphans the acked prefix
    s.node_down(0);
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::CrashPointLoss), 1, "{}", report.render());
    assert!(s.stats.crash_points_checked > 0);
}

#[test]
fn stale_retired_read_without_refetch_is_caught() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    // the real read path always refetches a stale extent first (clean);
    // serving the stale bytes themselves is the planted bug
    s.stale_serve(2, "/d/f", true);
    assert!(s.report().is_clean());
    s.stale_serve(2, "/d/f", false);
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::StaleServe), 1, "{}", report.render());
    assert_eq!(report.violations.first().map(|v| v.first_op), Some(2), "node in the report");
}

#[test]
fn torn_mid_epoch_snapshot_read_is_caught() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.register_proc(0, 0);
    // digest apply holds the seqlock odd over [100, 200)
    s.digest_apply(0, 1, 0, 100, 200);
    // the seqlock retry parks real readers at >= end: clean
    s.snapshot_read(0, 1, 0, 200);
    assert!(s.report().is_clean());
    // a read INSIDE the window saw a half-applied digest
    s.snapshot_read(0, 1, 0, 150);
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::TornRead), 1, "{}", report.render());
    // a different socket's window does not taint this one
    s.snapshot_read(0, 1, 1, 150);
    assert_eq!(s.report().count(SanViolationKind::TornRead), 1);
}

// ============================================= eviction planted bugs

#[test]
fn dirty_demotion_is_caught() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    // clean + replicated: demotion is the daemon working as designed
    s.replica_durable(0, 0, ChainId(3), 5);
    s.replica_durable(1, 0, ChainId(3), 5);
    s.extent_demote(0, ChainId(3), false, false);
    assert!(s.report().is_clean(), "{}", s.report().render());
    // planted bug: the sweep evicts an extent the version table still
    // calls dirty — its only fresh bytes are unreplicated NVM
    s.extent_demote(0, ChainId(3), true, false);
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::EvictUnreplicated), 1, "{}", report.render());
    assert!(s.stats.evictions_checked >= 2, "both demotions flow through the funnel");
}

#[test]
fn sole_durable_copy_never_demotes_to_capacity() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    // node 0 holds the only durable copy: pushing it off NVM into the
    // disaggregated capacity tier moves the last copy out of the local
    // persistence domain
    s.replica_durable(0, 0, ChainId(4), 5);
    s.extent_demote(0, ChainId(4), false, true);
    assert_eq!(s.report().count(SanViolationKind::EvictUnreplicated), 1, "{}", s.report().render());
    // with a second durable holder the same demotion is clean
    let mut s2 = SanState::new(SanMode::Full);
    s2.replica_durable(0, 0, ChainId(4), 5);
    s2.replica_durable(1, 0, ChainId(4), 5);
    s2.extent_demote(0, ChainId(4), false, true);
    assert!(s2.report().is_clean(), "{}", s2.report().render());
}

#[test]
fn retired_member_serving_evicted_bytes_is_caught() {
    if strict_env() {
        return;
    }
    let mut s = SanState::new(SanMode::Full);
    s.replica_durable(1, 0, ChainId(5), 5);
    s.replica_durable(2, 0, ChainId(5), 5);
    // node 1 retires from the chain, then the chain evicts elsewhere:
    // node 1's state copy predates the eviction
    s.replica_retired(1, ChainId(5));
    s.extent_demote(2, ChainId(5), false, false);
    // the real read path refetches the extent first: clean
    s.evicted_serve(1, ChainId(5), true);
    assert!(s.report().is_clean(), "{}", s.report().render());
    // planted bug: serving the pre-eviction bytes without a refetch
    s.evicted_serve(1, ChainId(5), false);
    let report = s.report();
    assert_eq!(report.count(SanViolationKind::EvictedByteServed), 1, "{}", report.render());
}

// ================================================== off-mode contract

/// One fixed mixed workload: batch submit, fsync (replication acks),
/// digest, rename, a 2-core ring over disjoint subtrees, reads.
fn drive_workload(c: &mut Cluster) -> Vec<assise::hw::Nanos> {
    let pid = c.spawn_process(0, 0);
    let mut latencies = Vec::new();
    let mut run = |c: &mut Cluster, ops: Vec<FsOp>| {
        for cq in c.submit(pid, ops) {
            latencies.push(cq.latency);
        }
    };
    run(c, vec![
        FsOp::Mkdir { path: "/t0".into() },
        FsOp::Mkdir { path: "/t1".into() },
        FsOp::Create { path: "/t0/f".into() },
        FsOp::Create { path: "/t1/f".into() },
    ]);
    let fd = c.open(pid, "/t0/f").unwrap();
    run(c, vec![
        FsOp::Write { fd, data: Payload::bytes(vec![7u8; 256]) },
        FsOp::Write { fd, data: Payload::bytes(vec![8u8; 256]) },
        FsOp::Fsync { fd },
    ]);
    c.digest_log(pid).unwrap();
    run(c, vec![
        FsOp::Rename { from: "/t0/f".into(), to: "/t0/g".into() },
        FsOp::Readdir { path: "/t0".into() },
        FsOp::Pread { fd, off: 0, len: 128 },
    ]);
    // 2-core ring, each core confined to its own subtree
    for cq in c.submit_mc(pid, 2, 42, vec![
        FsOp::Create { path: "/t0/a".into() },
        FsOp::Create { path: "/t1/a".into() },
        FsOp::Stat { path: "/t0/a".into() },
        FsOp::Stat { path: "/t1/a".into() },
        FsOp::Unlink { path: "/t0/a".into() },
        FsOp::Readdir { path: "/t1".into() },
    ]) {
        latencies.push(cq.latency);
    }
    latencies
}

#[test]
fn off_mode_trace_is_byte_identical_and_emits_nothing() {
    let mut off = Cluster::new(ClusterConfig::default().sanitize(SanMode::Off));
    let mut full = Cluster::new(ClusterConfig::default().sanitize(SanMode::Full));
    let lat_off = drive_workload(&mut off);
    let lat_full = drive_workload(&mut full);
    // the sanitizer only observes: virtual time must be identical
    assert_eq!(lat_off, lat_full, "SanMode must never touch clocks");
    // Off emits nothing at all
    assert_eq!(off.san.events().count(), 0);
    assert_eq!(off.san.stats.events_recorded, 0);
    assert_eq!(off.san.stats.accesses_checked, 0);
    assert!(off.san.report().is_clean());
    // Full observed the same run and found it correct
    assert!(full.san.stats.events_recorded > 0);
    assert!(full.san.stats.accesses_checked > 0);
    assert!(full.san.report().is_clean(), "{}", full.san.report().render());
}

// ============================================== clean-workload gates

#[test]
fn full_mode_is_clean_across_kill_and_failover() {
    if strict_env() {
        return;
    }
    // the crash_consistency prefix scenario, now under the sanitizer:
    // fsync'd prefix replicated, node killed, fail-over to the replica
    let mut c = Cluster::new(ClusterConfig::default().nodes(2).sanitize(SanMode::Full));
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    for i in 1..=3u8 {
        c.write(p, fd, Payload::bytes(vec![i; 100])).unwrap();
    }
    c.fsync(p, fd).unwrap();
    // unreplicated suffix: lost on kill, but never acked — not a fault
    c.write(p, fd, Payload::bytes(vec![4u8; 100])).unwrap();
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    let (np, _) = c.failover_process(p, 1, 0, t).unwrap();
    let fd2 = c.open(np, "/f").unwrap();
    assert_eq!(c.stat(np, "/f").unwrap().size, 300);
    let _ = c.pread(np, fd2, 0, 300).unwrap();
    // NVM survives reboot: recovery restores the copy
    let t2 = c.now(np);
    c.recover_node(0, t2).unwrap();
    c.write(np, fd2, Payload::bytes(vec![5u8; 100])).unwrap();
    c.fsync(np, fd2).unwrap();
    let report = c.san.report();
    assert!(report.is_clean(), "{}", report.render());
    assert!(c.san.stats.crash_points_checked > 0, "the kill swept crash points");
}

// ============================================ exhaustive exploration

#[test]
fn explore_enumerates_two_core_six_op_mutations_exhaustively() {
    if strict_env() {
        return;
    }
    let x = ExploreConfig {
        prep: vec![FsOp::Mkdir { path: "/t0".into() }, FsOp::Mkdir { path: "/t1".into() }],
        per_core: vec![
            vec![
                FsOp::Create { path: "/t0/a".into() },
                FsOp::Create { path: "/t0/b".into() },
                FsOp::Create { path: "/t0/c".into() },
            ],
            vec![
                FsOp::Create { path: "/t1/a".into() },
                FsOp::Create { path: "/t1/b".into() },
                FsOp::Create { path: "/t1/c".into() },
            ],
        ],
    };
    let report = explore(&ClusterConfig::default(), &x);
    // all-mutation (2 cores, 3+3 ops): every C(6,3) = 20 interleaving
    // is semantically distinct and every one must be replayed
    assert_eq!(report.schedules_run, 20);
    assert_eq!(report.schedules_pruned, 0);
    assert!(report.violations.is_empty(), "{:?}", report.violations);
}

#[test]
fn explore_collapses_commuting_reads_to_one_schedule() {
    if strict_env() {
        return;
    }
    let x = ExploreConfig {
        prep: vec![FsOp::Mkdir { path: "/t0".into() }, FsOp::Mkdir { path: "/t1".into() }],
        per_core: vec![
            vec![FsOp::Stat { path: "/t0".into() }, FsOp::Readdir { path: "/t0".into() }],
            vec![FsOp::Stat { path: "/t1".into() }, FsOp::Readdir { path: "/t1".into() }],
        ],
    };
    let report = explore(&ClusterConfig::default(), &x);
    assert_eq!(report.schedules_run, 1, "all-read rings have one canonical order");
    assert!(report.schedules_pruned > 0);
    assert!(report.violations.is_empty(), "{:?}", report.violations);
}
