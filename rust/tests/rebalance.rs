//! Live, cursor-preserving shard migration (ROADMAP "chain
//! rebalancing") and cross-chain rename acceptance tests:
//!
//! - `migrate_chain` under a live 4 KB-write workload loses no
//!   acknowledged write and keeps CRAQ reads flowing through the
//!   transition (old-chain members eligible until the new chain's
//!   `clean_upto` catches up);
//! - killing the old chain's head mid-drain, then failing the writer
//!   over, still recovers every acknowledged write, double-digests no
//!   entry (per-(pid, chain) watermarks are monotonic), and keeps reads
//!   served throughout — swept over seeds;
//! - a rename whose source and destination live on different chains is
//!   recoverable on EACH chain after `failover_process`, and its entry
//!   appears in both chains' replication cursors.

use std::collections::HashMap;

use assise::fs::Payload;
use assise::replication::ChainId;
use assise::sim::{Cluster, ClusterConfig, DistFs};
use assise::util::SplitMix64;

const CHUNK: u64 = 4096;

/// Writer on node 0, /hot pinned to chain [1] (old), nodes 2..3 free.
fn hot_cluster() -> (Cluster, usize, assise::fs::Fd) {
    let mut c = Cluster::new(ClusterConfig::default().nodes(4).repl_window(2));
    c.set_subtree_chain("/hot", vec![1], vec![]).unwrap();
    let pid = c.spawn_process(0, 0);
    c.mkdir(pid, "/hot").unwrap();
    let fd = c.create(pid, "/hot/f").unwrap();
    (c, pid, fd)
}

#[test]
fn live_migration_loses_no_acked_write_and_keeps_reads_flowing() {
    let (mut c, pid, fd) = hot_cluster();
    for k in 0..48u64 {
        c.pwrite(pid, fd, k * CHUNK, Payload::bytes(vec![(k % 251) as u8; CHUNK as usize]))
            .unwrap();
        if k % 8 == 7 {
            c.fsync(pid, fd).unwrap();
        }
        if k == 23 {
            // migrate mid-workload; the writer keeps running
            let t = c.now(pid);
            let rep = c.migrate_chain("/hot", vec![2], vec![], t).unwrap();
            assert_eq!(c.mgr.chain_id_for("/hot/f"), rep.new_chain);
            // reads flow DURING the transition: a reader whose clock
            // sits inside the catch-up window is served (by the new
            // member after its dirty confirm, or the retired one)
            let r = c.spawn_process(3, 0);
            c.set_now(r, t);
            let rfd = c.open(r, "/hot/f").unwrap();
            let got = c.pread(r, rfd, 0, CHUNK).unwrap().materialize();
            assert_eq!(got, vec![0u8; CHUNK as usize], "mid-transition read served correct bytes");
        }
    }
    c.fsync(pid, fd).unwrap();
    let acked = 48 * CHUNK; // every write is covered by a completed fsync

    // the writer's node dies; fail over onto the NEW chain's member
    let t = c.now(pid);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 2, 0, t).unwrap();
    assert_eq!(report.lost_entries, 0, "every write was fsync-acked");
    let fd2 = c.open(np, "/hot/f").unwrap();
    assert_eq!(c.stat(np, "/hot/f").unwrap().size, acked);
    for k in [0u64, 7, 23, 24, 40, 47] {
        let got = c.pread(np, fd2, k * CHUNK, CHUNK).unwrap().materialize();
        assert_eq!(got, vec![(k % 251) as u8; CHUNK as usize], "chunk {k} after failover");
    }
}

#[test]
fn reads_survive_retired_chain_loss_after_catchup() {
    // after the new chain catches up, the OLD member can die without
    // taking the subtree's reads down
    let (mut c, pid, fd) = hot_cluster();
    c.write(pid, fd, Payload::bytes(vec![9u8; 2 * CHUNK as usize])).unwrap();
    c.fsync(pid, fd).unwrap();
    c.digest_log(pid).unwrap();
    let t = c.now(pid);
    let rep = c.migrate_chain("/hot", vec![2], vec![], t).unwrap();
    c.kill_node(1, rep.catchup_at).unwrap();
    let r = c.spawn_process(3, 0);
    c.set_now(r, rep.catchup_at + 1_000_000);
    let rfd = c.open(r, "/hot/f").unwrap();
    assert_eq!(
        c.pread(r, rfd, 0, 2 * CHUNK).unwrap().materialize(),
        vec![9u8; 2 * CHUNK as usize]
    );
    assert!(c.reads_served_by[2] >= 1, "the new chain serves alone");
}

/// Snapshot every (pid, chain) digest watermark on every live replica.
fn watermark_snapshot(c: &Cluster) -> HashMap<(usize, usize, usize, ChainId), u64> {
    let mut m = HashMap::new();
    for (n, node) in c.nodes.iter().enumerate() {
        for (s, sock) in node.sockets.iter().enumerate() {
            for (&(pid, chain), &v) in &sock.sharedfs.applied_upto {
                m.insert((n, s, pid, chain), v);
            }
        }
    }
    m
}

#[test]
fn failure_during_migration_property() {
    // seeded sweep: kill the OLD chain's head mid-drain (windows in
    // flight), migrate, fail the writer over; no acknowledged write
    // lost, no entry double-digested (watermarks monotonic), reads
    // served throughout
    for seed in 0..12u64 {
        let mut rng = SplitMix64::new(0xB00 + seed);
        let mut c = Cluster::new(ClusterConfig::default().nodes(5).repl_window(2));
        // old chain [1, 2]: head 1 will die mid-drain; node 3 is the
        // migration target, node 4 hosts the reader
        c.set_subtree_chain("/hot", vec![1, 2], vec![]).unwrap();
        let pid = c.spawn_process(0, 0);
        c.mkdir(pid, "/hot").unwrap();
        let files = 1 + rng.below(3);
        let mut fds = Vec::new();
        for f in 0..files {
            fds.push(c.create(pid, &format!("/hot/f{f}")).unwrap());
        }
        let mut sizes = vec![0u64; files as usize];
        let mut acked_sizes = vec![0u64; files as usize];
        let writes = 16 + rng.below(24);
        let kill_at = rng.below(writes.max(2));
        let mut head_dead = false;
        for k in 0..writes {
            let f = rng.below(files) as usize;
            let len = CHUNK * (1 + rng.below(3));
            c.pwrite(pid, fds[f], sizes[f], Payload::synthetic(rng.next_u64(), len)).unwrap();
            sizes[f] += len;
            if rng.below(3) == 0 {
                c.fsync(pid, fds[f]).unwrap();
                acked_sizes.copy_from_slice(&sizes);
            }
            if k == kill_at && !head_dead {
                // the old head dies with replication windows in flight
                c.kill_node(1, c.now(pid)).unwrap();
                head_dead = true;
            }
        }
        // fsync the tail so "acked" is the whole stream, then migrate
        // away from the degraded chain
        for &fd in &fds {
            c.fsync(pid, fd).unwrap();
        }
        acked_sizes.copy_from_slice(&sizes);
        let t = c.now(pid);
        let before = watermark_snapshot(&c);
        let rep = c.migrate_chain("/hot", vec![3], vec![], t).unwrap();

        // reads served during the transition
        let r = c.spawn_process(4, 0);
        c.set_now(r, t);
        for f in 0..files as usize {
            if acked_sizes[f] == 0 {
                continue;
            }
            let rfd = c.open(r, &format!("/hot/f{f}")).unwrap();
            let got = c.pread(r, rfd, 0, acked_sizes[f]).unwrap();
            assert_eq!(got.len(), acked_sizes[f], "seed {seed}: mid-migration read");
        }

        // writer dies; replacement lands on the new chain's node
        let t2 = c.now(pid).max(c.now(r));
        c.kill_node(0, t2).unwrap();
        let (np, report) = c.failover_process(pid, 3, 0, t2).unwrap();
        assert_eq!(report.lost_entries, 0, "seed {seed}: every write was fsync-acked");
        for f in 0..files as usize {
            let path = format!("/hot/f{f}");
            assert_eq!(
                c.stat(np, &path).unwrap().size,
                acked_sizes[f],
                "seed {seed}: {path} size after failover"
            );
        }
        // watermarks never regressed (no entry re-applied below an
        // already-digested floor — the no-double-digest invariant)
        let after = watermark_snapshot(&c);
        for (key, v0) in &before {
            if let Some(v1) = after.get(key) {
                assert!(v1 >= v0, "seed {seed}: watermark {key:?} regressed {v0} -> {v1}");
            }
        }
        // the new chain's cursor covers the acked stream
        assert!(rep.generation > 0);
    }
}

#[test]
fn cross_chain_rename_recoverable_on_each_chain() {
    // /a pinned to chain [1], /b to chain [2]: a rename across them is
    // a two-chain namespace op
    let mut c = Cluster::new(ClusterConfig::default().nodes(4));
    let ka = c.set_subtree_chain("/a", vec![1], vec![]).unwrap();
    let kb = c.set_subtree_chain("/b", vec![2], vec![]).unwrap();
    let pid = c.spawn_process(0, 0);
    c.mkdir(pid, "/a").unwrap();
    c.mkdir(pid, "/b").unwrap();
    let fd = c.create(pid, "/a/x").unwrap();
    c.write(pid, fd, Payload::bytes(b"moved-across-chains".to_vec())).unwrap();
    c.rename(pid, "/a/x", "/b/y").unwrap();
    // ONE fsync batch carrying the create+write+rename
    c.fsync(pid, fd).unwrap();

    // the rename's seq is covered by BOTH chains' cursors
    let rename_seq = c.procs[pid].log.tail_seq();
    assert!(c.procs[pid].log.chain_cursor(ka) >= rename_seq, "source chain acked the rename");
    assert!(c.procs[pid].log.chain_cursor(kb) >= rename_seq, "destination chain acked the rename");

    // writer dies before any digest: fail over and recover
    let t = c.now(pid);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 2, 0, t).unwrap();
    assert_eq!(report.lost_entries, 0);
    // the move is visible: destination exists with the data, source gone
    let fd2 = c.open(np, "/b/y").unwrap();
    assert_eq!(c.pread(np, fd2, 0, 19).unwrap().materialize(), b"moved-across-chains");
    assert!(c.open(np, "/a/x").is_err(), "source path must not resurrect");
    // the DESTINATION chain's replica holds the file (no cross-chain
    // gossip needed at read time)
    assert!(c.nodes[2].sockets[0].sharedfs.store.exists("/b/y"));
    // and the source chain digested the move-away
    assert!(!c.nodes[1].sockets[0].sharedfs.store.exists("/a/x"));
}

#[test]
fn cross_chain_rename_of_digested_file_ships_the_data() {
    // the file's data was digested on the source chain BEFORE the
    // rename: the destination chain must materialize it at digest time
    // (fetch from the source replica), not serve an empty file
    let mut c = Cluster::new(ClusterConfig::default().nodes(4));
    c.set_subtree_chain("/a", vec![1], vec![]).unwrap();
    c.set_subtree_chain("/b", vec![2], vec![]).unwrap();
    let pid = c.spawn_process(0, 0);
    c.mkdir(pid, "/a").unwrap();
    c.mkdir(pid, "/b").unwrap();
    let fd = c.create(pid, "/a/x").unwrap();
    c.write(pid, fd, Payload::bytes(vec![6u8; 8192])).unwrap();
    c.fsync(pid, fd).unwrap();
    c.digest_log(pid).unwrap(); // data lives on chain [1] only

    c.rename(pid, "/a/x", "/b/y").unwrap();
    c.fsync(pid, fd).unwrap();
    c.digest_log(pid).unwrap();

    // the destination chain's replica holds the full content
    let s2 = &c.nodes[2].sockets[0].sharedfs.store;
    let ino = s2.resolve("/b/y").unwrap();
    assert_eq!(s2.stat_ino(ino).unwrap().size, 8192);
    assert_eq!(s2.read_at(ino, 0, 8192).unwrap().0.materialize(), vec![6u8; 8192]);
    // a reader far from both chains sees the moved file
    let r = c.spawn_process(3, 0);
    c.set_now(r, c.now(pid) + 1_000_000);
    let rfd = c.open(r, "/b/y").unwrap();
    assert_eq!(c.pread(r, rfd, 0, 8192).unwrap().materialize(), vec![6u8; 8192]);
    assert!(c.stat(r, "/a/x").is_err());
}

#[test]
fn migration_survives_rerouted_cross_chain_rename() {
    // rename across chains, then migrate the DESTINATION subtree: the
    // rename's entry must stay recoverable under the new routing
    let mut c = Cluster::new(ClusterConfig::default().nodes(5));
    c.set_subtree_chain("/a", vec![1], vec![]).unwrap();
    c.set_subtree_chain("/b", vec![2], vec![]).unwrap();
    let pid = c.spawn_process(0, 0);
    c.mkdir(pid, "/a").unwrap();
    c.mkdir(pid, "/b").unwrap();
    let fd = c.create(pid, "/a/x").unwrap();
    c.write(pid, fd, Payload::bytes(vec![3u8; 4096])).unwrap();
    c.rename(pid, "/a/x", "/b/y").unwrap();
    c.fsync(pid, fd).unwrap();

    let t = c.now(pid);
    c.migrate_chain("/b", vec![3], vec![], t).unwrap();

    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 3, 0, t).unwrap();
    assert_eq!(report.lost_entries, 0);
    let fd2 = c.open(np, "/b/y").unwrap();
    assert_eq!(c.pread(np, fd2, 0, 4096).unwrap().materialize(), vec![3u8; 4096]);
    assert!(c.nodes[3].sockets[0].sharedfs.store.exists("/b/y"));
}
