//! Capacity-pressure tiering integration tests: the background
//! NVM→SSD→capacity eviction daemon, promotion-on-read, and the
//! composition guarantees the tentpole makes:
//!
//! - NVM occupancy stays bounded under a fileset 10× the hot tier;
//! - log digestion NEVER deadlocks on a full NVM tier (the watermark
//!   sweep runs first, and the hard-budget fallback reclaims even when
//!   every sweep candidate is pinned);
//! - promotion-on-read pulls demoted bytes back into NVM, gated by the
//!   anti-thrash hysteresis window;
//! - with tiers uncapped the daemon is provably free (inert by
//!   construction, zero migrations, zero device accounting);
//! - eviction composes with replication and failure: `SanMode::Full`
//!   reports zero violations across eviction + kill/failover, and node
//!   recovery re-derives device accounting from the installed copy.

use assise::fs::{Payload, Tier};
use assise::sim::{Cluster, ClusterConfig, DistFs, SanMode};
use assise::util::SplitMix64;

const KB256: u64 = 256 << 10;

/// 1 MiB NVM hot tier over a 4 MiB SSD and a roomy capacity tier — the
/// pressure shape every test here leans on.
fn pressure_cfg(nodes: usize) -> ClusterConfig {
    ClusterConfig::default()
        .nodes(nodes)
        .hot_capacity(1 << 20)
        .ssd(4 << 20)
        .capacity_tier(64 << 20)
        .promote_hysteresis(1_000_000)
        .read_cache(4096)
}

#[test]
fn nvm_stays_bounded_under_10x_fileset() {
    let mut c = Cluster::new(pressure_cfg(2));
    let pid = c.spawn_process(0, 0);
    // 40 × 256 KiB = 10 MiB, ten times the 1 MiB hot tier
    for f in 0..40u64 {
        let fd = c.create(pid, &format!("/z{f}")).unwrap();
        c.pwrite(pid, fd, 0, Payload::zero(KB256)).unwrap();
        if f % 8 == 7 {
            c.fsync(pid, fd).unwrap();
            c.digest_log(pid).unwrap();
        }
    }
    let sfs = &c.nodes[0].sockets[0].sharedfs;
    assert_eq!(sfs.hot_overflow(), 0, "NVM occupancy exceeded the configured budget");
    let hot = sfs.store.bytes_in_tier(Tier::Hot);
    assert!(hot <= 1 << 20, "hot tier holds {hot} bytes, budget is 1 MiB");
    assert!(c.tiering.stats.demotions > 0, "a 10x fileset never crossed the watermark");
    assert!(
        c.tiering.stats.demotions_to_capacity > 0,
        "a 4 MiB SSD cannot hold a 10 MiB fileset: bytes must spill to the capacity tier"
    );
    assert!(c.nodes[0].cap.used() > 0, "capacity device never charged for the spill");
    assert_eq!(c.tiering.stats.free_underflows, 0, "device accounting went negative");
}

#[test]
fn digest_never_deadlocks_on_a_full_nvm_tier() {
    // every file is as large as the ENTIRE hot tier: each digest must
    // reclaim the full budget before its bytes fit, through the sweep
    // or — when the version table pins every candidate — the
    // hard-budget fallback; a wedged digest fails the unwrap below
    let mut c = Cluster::new(
        ClusterConfig::default()
            .nodes(2)
            .hot_capacity(256 << 10)
            .ssd(1 << 20)
            .capacity_tier(64 << 20),
    );
    let pid = c.spawn_process(0, 0);
    for f in 0..16u64 {
        let fd = c.create(pid, &format!("/d{f}")).unwrap();
        c.pwrite(pid, fd, 0, Payload::zero(KB256)).unwrap();
        c.fsync(pid, fd).unwrap();
        c.digest_log(pid).unwrap();
        assert_eq!(
            c.nodes[0].sockets[0].sharedfs.hot_overflow(),
            0,
            "digest {f} left NVM over budget"
        );
    }
    assert!(c.tiering.stats.demotions > 0);
    assert!(
        c.tiering.stats.demotions_to_capacity > 0,
        "16 files x 256 KiB must overflow the 1 MiB SSD into the capacity tier"
    );
}

#[test]
fn promotion_on_read_pulls_demoted_bytes_back() {
    // hysteresis 0: a demoted extent may promote on the very next read
    let mut c = Cluster::new(pressure_cfg(2).promote_hysteresis(0));
    let pid = c.spawn_process(0, 0);
    let mut fds = Vec::new();
    for f in 0..8u64 {
        let fd = c.create(pid, &format!("/p{f}")).unwrap();
        c.pwrite(pid, fd, 0, Payload::zero(KB256)).unwrap();
        fds.push(fd);
        if f % 4 == 3 {
            c.fsync(pid, fd).unwrap();
            c.digest_log(pid).unwrap();
        }
    }
    assert!(c.tiering.stats.demotions > 0, "2 MiB into a 1 MiB tier must demote");
    // read every file: the demoted ones route through SSD/capacity and
    // promote back into NVM (admission room exists below the watermark)
    for &fd in &fds {
        let out = c.pread(pid, fd, 0, 64 << 10).unwrap();
        assert_eq!(out.len(), 64 << 10);
    }
    assert!(c.tiering.stats.promotions > 0, "no demoted read promoted");
    assert!(c.tiering.stats.promoted_bytes > 0);
    assert_eq!(c.nodes[0].sockets[0].sharedfs.hot_overflow(), 0, "promotion overfilled NVM");
}

#[test]
fn hysteresis_suppresses_promotion_thrash() {
    // an (effectively) infinite anti-thrash window: demoted bytes must
    // serve from their demoted tier, never bounce straight back
    let mut c = Cluster::new(pressure_cfg(2).promote_hysteresis(u64::MAX >> 1));
    let pid = c.spawn_process(0, 0);
    let mut fds = Vec::new();
    for f in 0..8u64 {
        let fd = c.create(pid, &format!("/h{f}")).unwrap();
        c.pwrite(pid, fd, 0, Payload::zero(KB256)).unwrap();
        fds.push(fd);
        if f % 4 == 3 {
            c.fsync(pid, fd).unwrap();
            c.digest_log(pid).unwrap();
        }
    }
    assert!(c.tiering.stats.demotions > 0);
    for &fd in &fds {
        let out = c.pread(pid, fd, 0, 64 << 10).unwrap();
        assert_eq!(out.len(), 64 << 10, "suppressed promotion must not break the read");
    }
    assert_eq!(c.tiering.stats.promotions, 0, "promotion thrashed through the window");
    assert!(c.tiering.stats.promotion_suppressed > 0, "the gate never even engaged");
}

#[test]
fn uncapped_tiers_leave_the_daemon_free() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    assert!(c.tiering.inert(), "default config must be inert by construction");
    let pid = c.spawn_process(0, 0);
    let fd = c.create(pid, "/f").unwrap();
    for k in 0..64u64 {
        c.pwrite(pid, fd, k * 4096, Payload::zero(4096)).unwrap();
    }
    c.fsync(pid, fd).unwrap();
    c.digest_log(pid).unwrap();
    let out = c.pread(pid, fd, 0, 4096).unwrap();
    assert_eq!(out.len(), 4096);
    assert!(c.tiering.stats.is_quiescent(), "inert daemon did tiering work");
    assert_eq!(c.nodes[0].ssd.used(), 0, "no eviction, no SSD accounting");
    assert_eq!(c.nodes[0].cap.used(), 0, "no eviction, no capacity accounting");
}

#[test]
fn san_full_is_clean_across_eviction_and_failover() {
    // the ISSUE's sanitizer acceptance: a pressured workload that
    // evicts, reads demoted bytes, then loses its node — under
    // SanMode::Full the whole run must report zero violations
    let mut c = Cluster::new(pressure_cfg(3).replication(3).sanitize(SanMode::Full));
    let pid = c.spawn_process(0, 0);
    let fd = c.create(pid, "/f").unwrap();
    const CHUNK: u64 = 32 << 10;
    const OPS: u64 = 96; // 3 MiB through a 1 MiB hot tier
    for k in 0..OPS {
        c.pwrite(pid, fd, k * CHUNK, Payload::zero(CHUNK)).unwrap();
        c.fsync(pid, fd).unwrap();
        if k % 16 == 15 {
            c.digest_log(pid).unwrap();
        }
    }
    // demoted reads route through the funnel (refetch, never stale)
    let mut rng = SplitMix64::new(7);
    for _ in 0..8 {
        let off = rng.below(OPS) * CHUNK;
        let out = c.pread(pid, fd, off, CHUNK).unwrap();
        assert_eq!(out.len() as u64, CHUNK);
    }
    assert!(c.tiering.stats.demotions > 0, "no eviction pressure generated");
    assert!(c.san.stats.evictions_checked > 0, "demotions bypassed the sanitizer funnel");
    let t = c.now(pid);
    c.kill_node(0, t).unwrap();
    let (np, report) = c.failover_process(pid, 1, 0, t).unwrap();
    assert_eq!(report.lost_entries, 0, "acked write lost under eviction pressure");
    assert_eq!(c.stat(np, "/f").unwrap().size, OPS * CHUNK);
    let fd2 = c.open(np, "/f").unwrap();
    let out = c.pread(np, fd2, 0, CHUNK).unwrap();
    assert_eq!(out.len() as u64, CHUNK);
    let rep = c.san.report();
    assert!(rep.is_clean(), "{}", rep.render());
}

#[test]
fn recovery_rebuilds_demoted_tier_accounting() {
    // node 1 (the replica) dies after its daemon demoted digested bytes;
    // recovery installs a peer copy whose tier layout differs from the
    // dead copy's — device accounting must be re-derived from the
    // installed state, not left at stale pre-crash gauges
    let mut c = Cluster::new(pressure_cfg(2));
    let pid = c.spawn_process(0, 0);
    for f in 0..10u64 {
        let fd = c.create(pid, &format!("/r{f}")).unwrap();
        c.pwrite(pid, fd, 0, Payload::zero(KB256)).unwrap();
        c.fsync(pid, fd).unwrap();
        if f % 2 == 1 {
            c.digest_log(pid).unwrap();
        }
    }
    assert!(c.tiering.stats.demotions > 0);
    let t = c.now(pid);
    c.kill_node(1, t).unwrap();
    let t2 = c.now(pid);
    c.recover_node(1, t2).unwrap();
    let cold: u64 =
        c.nodes[1].sockets.iter().map(|s| s.sharedfs.store.bytes_in_tier(Tier::Cold)).sum();
    let cap: u64 =
        c.nodes[1].sockets.iter().map(|s| s.sharedfs.store.bytes_in_tier(Tier::Capacity)).sum();
    assert_eq!(
        c.nodes[1].ssd.used(),
        cold,
        "recovery must re-derive SSD accounting from the installed copy"
    );
    assert_eq!(
        c.nodes[1].cap.used(),
        cap,
        "recovery must re-derive capacity accounting from the installed copy"
    );
    assert_eq!(c.tiering.stats.free_underflows, 0);
    // the cluster keeps working after recovery
    let fd = c.create(pid, "/after").unwrap();
    c.pwrite(pid, fd, 0, Payload::zero(4096)).unwrap();
    c.fsync(pid, fd).unwrap();
    c.digest_log(pid).unwrap();
}
