//! Property tests for the zero-copy payload representation and the
//! extent-map overlay invariants, using the same in-crate seeded harness
//! as `prop_invariants.rs` (no proptest in the offline environment).
//!
//! Three families:
//! 1. Arc-slice `Payload` slice/concat chains are byte-identical to the
//!    materialized equivalent AND copy zero payload bytes while composing.
//! 2. `ExtentMap` overlay fuzz: random writes/truncates against a flat
//!    `Vec<u8>` model — contents match, extents never overlap, and the
//!    incremental per-tier counters equal a full recount.
//! 3. `FileStore` namespace fuzz: the indexed `resolve` agrees with an
//!    uncached walk after random create/mkdir/rename/unlink churn.

use assise::fs::payload::stats;
use assise::fs::{Cred, ExtentMap, FileStore, Mode, Payload, Tier, TIER_COUNT};
use assise::util::SplitMix64;

const SEEDS: u64 = 30;

// ------------------------------------------------ payload slice/concat

/// Build a random composition (slices + concats) over `base`, returning
/// the payload and the equivalent byte range composition of `model`.
fn random_composition(
    rng: &mut SplitMix64,
    base: &Payload,
    model: &[u8],
    depth: usize,
) -> (Payload, Vec<u8>) {
    if depth == 0 || rng.below(3) == 0 {
        let len = base.len();
        let off = rng.below(len);
        let l = 1 + rng.below(len - off);
        return (base.slice(off, l), model[off as usize..(off + l) as usize].to_vec());
    }
    let n = 2 + rng.below(3) as usize;
    let mut parts = Vec::new();
    let mut bytes = Vec::new();
    for _ in 0..n {
        let (p, b) = random_composition(rng, base, model, depth - 1);
        parts.push(p);
        bytes.extend_from_slice(&b);
    }
    (Payload::concat(&parts), bytes)
}

#[test]
fn prop_slice_concat_chains_match_materialized_and_copy_nothing() {
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(seed);
        let size = 1024 + rng.below(64 * 1024);
        let model: Vec<u8> = (0..size).map(|i| (i as u8) ^ (seed as u8)).collect();
        let base = Payload::bytes(model.clone());

        stats::reset();
        let (composed, expect) = random_composition(&mut rng, &base, &model, 3);
        // further slice the composition (exercises chain slicing)
        let off = rng.below(composed.len());
        let l = 1 + rng.below(composed.len() - off);
        let sub = composed.slice(off, l);
        assert_eq!(
            stats::copied_bytes(),
            0,
            "seed {seed}: slice/concat composition copied bytes"
        );
        assert_eq!(
            stats::materializations(),
            0,
            "seed {seed}: composition materialized"
        );

        // semantics: byte-identical to the model composition
        assert_eq!(composed.materialize(), expect, "seed {seed}: composed bytes");
        assert_eq!(
            sub.materialize(),
            &expect[off as usize..(off + l) as usize],
            "seed {seed}: chain slice bytes"
        );
    }
}

#[test]
fn prop_mixed_representation_concat_matches() {
    // bytes + synthetic + zero mixed in one chain
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(100 + seed);
        let b = Payload::bytes((0..256u64).map(|i| (i * seed) as u8).collect());
        let s = Payload::synthetic(seed, 300);
        let z = Payload::zero(100);
        let c = Payload::concat(&[b.slice(10, 100), s.slice(50, 200), z.slice(0, 60)]);
        let mut expect = b.materialize()[10..110].to_vec();
        expect.extend_from_slice(&s.materialize()[50..250]);
        expect.extend_from_slice(&vec![0u8; 60]);
        assert_eq!(c.materialize(), expect, "seed {seed}");
        // random re-slices agree with the model
        for _ in 0..20 {
            let off = rng.below(c.len());
            let l = 1 + rng.below(c.len() - off);
            assert_eq!(
                c.slice(off, l).materialize(),
                &expect[off as usize..(off + l) as usize],
                "seed {seed} off {off} len {l}"
            );
        }
    }
}

// ----------------------------------------------------- extent map fuzz

#[test]
fn prop_extent_overlay_fuzz_no_overlap_and_content() {
    const FILE: u64 = 64 * 1024;
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(200 + seed);
        let mut m = ExtentMap::new();
        let mut model = vec![0u8; FILE as usize];
        for step in 0..200u64 {
            let op = rng.below(10);
            if op < 7 {
                // random overlay write
                let off = rng.below(FILE - 1);
                let len = 1 + rng.below((FILE - off).min(4096));
                let tier = match rng.below(4) {
                    0 => Tier::Hot,
                    1 => Tier::Reserve,
                    2 => Tier::Cold,
                    _ => Tier::Capacity,
                };
                let fill = (step as u8).wrapping_mul(31).wrapping_add(seed as u8);
                m.write(off, Payload::bytes(vec![fill; len as usize]), tier, step);
                model[off as usize..(off + len) as usize].fill(fill);
            } else if op < 9 {
                // synthetic write (different representation, same rules)
                let off = rng.below(FILE - 1);
                let len = 1 + rng.below((FILE - off).min(4096));
                let p = Payload::synthetic(rng.next_u64(), len);
                let bytes = p.materialize();
                m.write(off, p, Tier::Hot, step);
                model[off as usize..(off + len) as usize].copy_from_slice(&bytes);
            } else {
                // truncate, then the tail reads as a hole (zeros)
                let size = rng.below(FILE);
                m.truncate(size);
                model[size as usize..].fill(0);
            }

            // invariant: extents sorted, non-overlapping, non-empty
            let mut prev_end = 0u64;
            for (&s, e) in m.iter() {
                assert!(e.len() > 0, "seed {seed} step {step}: empty extent at {s}");
                assert!(
                    s >= prev_end,
                    "seed {seed} step {step}: overlap at {s} (prev end {prev_end})"
                );
                prev_end = s + e.len();
            }
            // invariant: incremental tier counters == recount
            let mut recount = [0u64; TIER_COUNT];
            for (_, e) in m.iter() {
                recount[e.tier.idx()] += e.len();
            }
            assert_eq!(m.tier_snapshot(), recount, "seed {seed} step {step}: counters");
        }
        // final content equivalence
        let (p, _) = m.read(0, FILE);
        assert_eq!(p.materialize(), model, "seed {seed}: content diverged");
    }
}

// ------------------------------------------------- namespace index fuzz

#[test]
fn prop_indexed_resolve_agrees_with_walk() {
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(300 + seed);
        let mut s = FileStore::new();
        let mut dirs: Vec<String> = vec![];
        let mut files: Vec<String> = vec![];
        let mut uniq = 0;
        for step in 0..150u64 {
            match rng.below(10) {
                0..=2 => {
                    let parent = if dirs.is_empty() || rng.below(2) == 0 {
                        String::new()
                    } else {
                        dirs[rng.below(dirs.len() as u64) as usize].clone()
                    };
                    let p = format!("{parent}/d{uniq}");
                    uniq += 1;
                    if s.mkdir(&p, Mode::DEFAULT_DIR, Cred::ROOT, step).is_ok() {
                        dirs.push(p);
                    }
                }
                3..=5 => {
                    let parent = if dirs.is_empty() || rng.below(2) == 0 {
                        String::new()
                    } else {
                        dirs[rng.below(dirs.len() as u64) as usize].clone()
                    };
                    let p = format!("{parent}/f{uniq}");
                    uniq += 1;
                    if s.create(&p, Mode::DEFAULT_FILE, Cred::ROOT, step).is_ok() {
                        files.push(p);
                    }
                }
                6..=7 if !dirs.is_empty() => {
                    // rename a whole directory subtree
                    let i = rng.below(dirs.len() as u64) as usize;
                    let from = dirs[i].clone();
                    let to = format!("/r{uniq}");
                    uniq += 1;
                    if s.rename(&from, &to, step).is_ok() {
                        // re-prefix every tracked path under `from`
                        let prefix = format!("{from}/");
                        let mut fix = |p: &mut String| {
                            if *p == from {
                                *p = to.clone();
                            } else if p.starts_with(&prefix) {
                                *p = format!("{to}{}", &p[from.len()..]);
                            }
                        };
                        dirs.iter_mut().for_each(&mut fix);
                        files.iter_mut().for_each(&mut fix);
                    }
                }
                _ if !files.is_empty() => {
                    let i = rng.below(files.len() as u64) as usize;
                    let p = files.remove(i);
                    let _ = s.unlink(&p, step);
                }
                _ => {}
            }
        }
        // every tracked live path: cached resolve == uncached walk
        for p in dirs.iter().chain(files.iter()) {
            let cached = s.resolve(p);
            let walked = s.resolve_uncached(p);
            assert_eq!(cached, walked, "seed {seed}: divergence at {p}");
            assert!(cached.is_ok(), "seed {seed}: tracked path {p} lost");
            // reverse index agrees too
            let ino = cached.unwrap();
            assert_eq!(s.path_of(ino), Some(p.as_str()), "seed {seed}: path_of({ino})");
        }
        // tier counters still exact after namespace churn
        let recount = s.recount_tier_bytes();
        for t in [Tier::Hot, Tier::Reserve, Tier::Cold, Tier::Capacity] {
            assert_eq!(s.bytes_in_tier(t), recount[t.idx()], "seed {seed}: tier {t:?}");
        }
    }
}
