//! xfstests-style compliance battery (paper §5: "Assise passed all 75
//! generic xfstests recommended for NFS"). Each test exercises a POSIX
//! semantic the generic suite checks — including the cases the paper
//! reports NFS (35, 423, 465, 469) and Ceph (91, 213, 258, 263, 313,
//! 451) failing, which Assise must pass.

use assise::fs::{FsError, Payload};
use assise::sim::{Cluster, ClusterConfig, DistFs};

fn cluster() -> Cluster {
    Cluster::new(ClusterConfig::default().nodes(2))
}

#[test]
fn basic_create_write_read() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"abc".to_vec())).unwrap();
    assert_eq!(c.pread(p, fd, 0, 3).unwrap().materialize(), b"abc");
}

#[test]
fn overwrite_middle_of_file() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"aaaaaaaaaa".to_vec())).unwrap();
    c.pwrite(p, fd, 3, Payload::bytes(b"BB".to_vec())).unwrap();
    assert_eq!(c.pread(p, fd, 0, 10).unwrap().materialize(), b"aaaBBaaaaa");
}

#[test]
fn sparse_write_reads_zero_holes() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.pwrite(p, fd, 8192, Payload::bytes(b"end".to_vec())).unwrap();
    let data = c.pread(p, fd, 0, 8195).unwrap().materialize();
    assert_eq!(&data[..8192], &vec![0u8; 8192][..]);
    assert_eq!(&data[8192..], b"end");
}

#[test]
fn mtime_updates_on_write() {
    // the xfstests-423-style check that NFS fails (attribute caching)
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    let t1 = c.stat(p, "/f").unwrap().mtime;
    c.write(p, fd, Payload::bytes(b"x".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    let t2 = c.stat(p, "/f").unwrap().mtime;
    assert!(t2 >= t1);
    assert_eq!(c.stat(p, "/f").unwrap().size, 1);
}

#[test]
fn rename_is_atomic_replace() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let a = c.create(p, "/a").unwrap();
    c.write(p, a, Payload::bytes(b"new".to_vec())).unwrap();
    let b = c.create(p, "/b").unwrap();
    c.write(p, b, Payload::bytes(b"old".to_vec())).unwrap();
    c.rename(p, "/a", "/b").unwrap();
    assert!(matches!(c.open(p, "/a"), Err(FsError::NotFound(_))));
    let fd = c.open(p, "/b").unwrap();
    assert_eq!(c.pread(p, fd, 0, 3).unwrap().materialize(), b"new");
}

#[test]
fn unlink_then_recreate_fresh_content() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"old-old-old".to_vec())).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    c.unlink(p, "/f").unwrap();
    assert!(matches!(c.open(p, "/f"), Err(FsError::NotFound(_))));
    let fd2 = c.create(p, "/f").unwrap();
    c.write(p, fd2, Payload::bytes(b"new".to_vec())).unwrap();
    assert_eq!(c.stat(p, "/f").unwrap().size, 3);
    assert_eq!(c.pread(p, fd2, 0, 3).unwrap().materialize(), b"new");
}

#[test]
fn mkdir_nested_and_rename_dir() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    c.mkdir(p, "/d").unwrap();
    c.mkdir(p, "/d/e").unwrap();
    let fd = c.create(p, "/d/e/f").unwrap();
    c.write(p, fd, Payload::bytes(b"deep".to_vec())).unwrap();
    c.rename(p, "/d/e", "/d/renamed").unwrap();
    let fd2 = c.open(p, "/d/renamed/f").unwrap();
    assert_eq!(c.pread(p, fd2, 0, 4).unwrap().materialize(), b"deep");
}

#[test]
fn cross_process_visibility_is_linearizable() {
    // stronger than close-to-open: an fsync'd write is visible to a
    // second process immediately (via lease handoff), no reopen needed
    let mut c = cluster();
    let p1 = c.spawn_process(0, 0);
    let p2 = c.spawn_process(1, 0);
    c.mkdir(p1, "/shared").unwrap();
    let fd = c.create(p1, "/shared/f").unwrap();
    c.write(p1, fd, Payload::bytes(b"v1".to_vec())).unwrap();
    c.set_now(p2, c.now(p1));
    let fd2 = c.open(p2, "/shared/f").unwrap();
    assert_eq!(c.pread(p2, fd2, 0, 2).unwrap().materialize(), b"v1");
    // and p2's writes become visible to p1 in turn
    c.pwrite(p2, fd2, 0, Payload::bytes(b"v2".to_vec())).unwrap();
    c.set_now(p1, c.now(p2));
    assert_eq!(c.pread(p1, fd, 0, 2).unwrap().materialize(), b"v2");
}

#[test]
fn directory_listing_via_stat() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    c.mkdir(p, "/dir").unwrap();
    for i in 0..10 {
        c.create(p, &format!("/dir/f{i}")).unwrap();
    }
    c.fsync_all(p);
    for i in 0..10 {
        assert!(c.stat(p, &format!("/dir/f{i}")).is_ok());
    }
    let st = c.stat(p, "/dir").unwrap();
    assert!(st.is_dir);
}

#[test]
fn enoent_and_eexist_errors() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    assert!(matches!(c.open(p, "/missing"), Err(FsError::NotFound(_))));
    assert!(matches!(c.unlink(p, "/missing"), Err(FsError::NotFound(_))));
    c.create(p, "/f").unwrap();
    assert!(matches!(c.create(p, "/f"), Err(FsError::AlreadyExists(_))));
    assert!(matches!(c.mkdir(p, "/f"), Err(FsError::AlreadyExists(_))));
    assert!(matches!(
        c.create(p, "/nodir/f"),
        Err(FsError::NotFound(_)) | Err(FsError::LeaseConflict(_))
    ));
}

#[test]
fn bad_fd_rejected() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    assert!(matches!(c.read(p, 99, 10), Err(FsError::BadFd(99))));
    assert!(matches!(
        c.write(p, 99, Payload::zero(1)),
        Err(FsError::BadFd(99))
    ));
    assert!(matches!(c.close(p, 99), Err(FsError::BadFd(99))));
}

#[test]
fn read_past_eof_truncates() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/f").unwrap();
    c.write(p, fd, Payload::bytes(b"short".to_vec())).unwrap();
    assert_eq!(c.pread(p, fd, 0, 100).unwrap().len(), 5);
    assert_eq!(c.pread(p, fd, 100, 10).unwrap().len(), 0);
}

#[test]
fn large_file_multi_extent_roundtrip() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/big").unwrap();
    // 64 x 64KB writes = 4 MB, then verify a scattered sample
    for i in 0..64u64 {
        c.pwrite(p, fd, i * 65536, Payload::synthetic(i, 65536)).unwrap();
    }
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    for i in [0u64, 17, 40, 63] {
        let d = c.pread(p, fd, i * 65536, 64).unwrap();
        assert_eq!(d.materialize(), Payload::synthetic(i, 65536).slice(0, 64).materialize());
    }
    assert_eq!(c.stat(p, "/big").unwrap().size, 4 << 20);
}

trait FsyncAll {
    fn fsync_all(&mut self, pid: usize);
}

impl FsyncAll for Cluster {
    fn fsync_all(&mut self, pid: usize) {
        self.replicate_log(pid).unwrap();
        self.digest_log(pid).unwrap();
    }
}

// ------------------------------------------------------- added coverage

#[test]
fn truncate_shrink_and_extend() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/t").unwrap();
    c.write(p, fd, Payload::bytes(b"abcdefgh".to_vec())).unwrap();
    c.truncate(p, "/t", 3).unwrap();
    assert_eq!(c.stat(p, "/t").unwrap().size, 3);
    assert_eq!(c.pread(p, fd, 0, 10).unwrap().materialize(), b"abc");
    // extend: reads zeros past the old end
    c.truncate(p, "/t", 6).unwrap();
    assert_eq!(c.stat(p, "/t").unwrap().size, 6);
    assert_eq!(c.pread(p, fd, 0, 6).unwrap().materialize(), b"abc\0\0\0");
}

#[test]
fn truncate_survives_digest_and_failover() {
    let mut c = cluster();
    let p = c.spawn_process(0, 0);
    let fd = c.create(p, "/t").unwrap();
    c.write(p, fd, Payload::bytes(vec![7u8; 4096])).unwrap();
    c.truncate(p, "/t", 100).unwrap();
    c.fsync(p, fd).unwrap();
    c.digest_log(p).unwrap();
    let t = c.now(p);
    c.kill_node(0, t).unwrap();
    let (np, _) = c.failover_process(p, 1, 0, t).unwrap();
    assert_eq!(c.stat(np, "/t").unwrap().size, 100);
}

#[test]
fn permissions_enforced_for_non_owner() {
    use assise::fs::Cred;
    let mut c = cluster();
    let alice = c.spawn_process(0, 0);
    let bob = c.spawn_process(1, 0);
    c.set_cred(alice, Cred::new(1000, 1000));
    c.set_cred(bob, Cred::new(2000, 2000));
    c.mkdir(alice, "/home").unwrap();
    let fd = c.create(alice, "/home/secret").unwrap();
    c.write(alice, fd, Payload::bytes(b"mine".to_vec())).unwrap();
    c.fsync(alice, fd).unwrap();
    c.digest_log(alice).unwrap();
    // default 0644: bob can read but not write
    c.set_now(bob, c.now(alice));
    let bfd = c.open(bob, "/home/secret").unwrap();
    assert_eq!(c.pread(bob, bfd, 0, 4).unwrap().materialize(), b"mine");
    assert!(matches!(
        c.pwrite(bob, bfd, 0, Payload::bytes(b"!".to_vec())),
        Err(FsError::PermissionDenied(_))
    ));
    // alice still writes fine
    c.pwrite(alice, fd, 0, Payload::bytes(b"MINE".to_vec())).unwrap();
}

#[test]
fn root_bypasses_permissions() {
    use assise::fs::Cred;
    let mut c = cluster();
    let alice = c.spawn_process(0, 0);
    let root = c.spawn_process(0, 1);
    c.set_cred(alice, Cred::new(1000, 1000));
    c.mkdir(alice, "/h").unwrap();
    let fd = c.create(alice, "/h/f").unwrap();
    c.write(alice, fd, Payload::bytes(b"x".to_vec())).unwrap();
    c.set_now(root, c.now(alice));
    let rfd = c.open(root, "/h/f").unwrap();
    c.pwrite(root, rfd, 0, Payload::bytes(b"y".to_vec())).unwrap();
}
