//! Gray-failure property suite: randomized op scripts under each fault
//! class — link partitions (one-way, two-way), stragglers, message
//! drop/reorder, flapping nodes, and clock skew — asserting the
//! availability invariants the fault layer exists to protect:
//!
//! 1. no acknowledged (fsync'd) write is ever lost across a failover,
//!    clean-kill or partition-suspected alike;
//! 2. no read returns a stale or torn payload, straggler in the chain
//!    or not;
//! 3. lease exclusivity survives per-process clock skew;
//! 4. every unreachable outcome surfaces as an explicit
//!    `FsError::ChainUnavailable` — never a silent wrong answer;
//! 5. the same fault seed replays an identical virtual-time trace.

use assise::fs::{FsError, Payload};
use assise::sim::{Cluster, ClusterConfig, DistFs, FaultPlan};
use assise::util::SplitMix64;

fn encode(v: u64) -> Vec<u8> {
    v.to_le_bytes().to_vec()
}

fn decode(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

/// Committed state on a 4-node cluster: version 1 of `/v` written,
/// fsync'd, and digested by a writer on node 0 (chain `[0, 1, 2]`).
fn seeded_cluster() -> (Cluster, usize, u64) {
    let mut c = Cluster::new(ClusterConfig::default().nodes(4).replication(3).read_cache(0));
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/v").unwrap();
    c.pwrite(w, fd, 0, Payload::bytes(encode(1))).unwrap();
    c.fsync(w, fd).unwrap();
    c.digest_log(w).unwrap();
    (c, w, fd)
}

// ================================================== partitions

#[test]
fn two_way_partition_surfaces_chain_unavailable_then_heals() {
    let (mut c, w, fd) = seeded_cluster();
    let r = c.spawn_process(3, 0); // off-chain reader
    c.set_now(r, c.now(w) + 1_000_000);
    let f = c.open(r, "/v").unwrap();
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 1);

    // cut the reader's node off from every replica
    c.isolate_node(3).unwrap();
    let res = c.pread(r, f, 0, 8);
    assert!(
        matches!(res, Err(FsError::ChainUnavailable(_))),
        "partitioned read must surface ChainUnavailable, got {res:?}"
    );
    assert!(c.fault_stats.partitioned_sends_refused > 0);

    // a new committed version lands while the reader is cut off
    c.pwrite(w, fd, 0, Payload::bytes(encode(2))).unwrap();
    c.fsync(w, fd).unwrap();
    c.digest_log(w).unwrap();

    // heal: reads flow again and serve the committed version, never the
    // stale pre-partition payload
    c.heal_all_partitions();
    c.set_now(r, c.now(w) + 1_000_000);
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 2);
}

#[test]
fn oneway_partition_is_asymmetric_but_blocks_round_trips() {
    let (mut c, w, _fd) = seeded_cluster();
    let r = c.spawn_process(3, 0);
    c.set_now(r, c.now(w) + 1_000_000);
    let f = c.open(r, "/v").unwrap();

    // blocking only one outbound link leaves other candidates serving
    c.partition_oneway(3, 2).unwrap();
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 1);

    // blocking ALL outbound links starves the reader even though every
    // reverse direction is still up — an RPC needs the round trip
    c.partition_oneway(3, 0).unwrap();
    c.partition_oneway(3, 1).unwrap();
    assert!(c.fault.reachable(0, 3) && c.fault.reachable(1, 3) && c.fault.reachable(2, 3));
    assert!(matches!(c.pread(r, f, 0, 8), Err(FsError::ChainUnavailable(_))));
}

#[test]
fn partitioned_chain_hop_fails_fsync_explicitly() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/f").unwrap();
    c.pwrite(w, fd, 0, Payload::zero(4096)).unwrap();
    c.fsync(w, fd).unwrap(); // healthy chain acks

    // head -> successor link dies; the local append still succeeds but
    // the replication ack cannot form
    c.partition(0, 1).unwrap();
    c.pwrite(w, fd, 4096, Payload::zero(4096)).unwrap();
    let res = c.fsync(w, fd);
    assert!(
        matches!(res, Err(FsError::ChainUnavailable(_))),
        "fsync over a partitioned hop must refuse, got {res:?}"
    );
    assert!(c.fault_stats.partitioned_sends_refused > 0);

    // heal: the suffix replicates and the ack completes
    c.heal_partition(0, 1).unwrap();
    c.fsync(w, fd).unwrap();
}

#[test]
fn no_acked_write_lost_across_partition_failover() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/f").unwrap();
    for k in 0..32u64 {
        c.pwrite(w, fd, k * 4096, Payload::zero(4096)).unwrap();
        c.fsync(w, fd).unwrap(); // every write acked
    }
    let t = c.now(w);

    // gray failure: node 0 still runs, but the manager declares it via
    // the partition-suspect path (one extra suspicion round)
    let detected = c.suspect_partitioned_node(0, t).unwrap();
    assert_eq!(
        detected,
        t + c.cfg.heartbeat_interval + 2 * c.cfg.suspect_timeout,
        "gray detection charges heartbeat + two suspect windows"
    );

    let (np, report) = c.failover_process(w, 1, 0, t).unwrap();
    assert_eq!(report.detected_at, detected);
    assert_eq!(report.lost_entries, 0, "acked writes must survive failover");
    assert_eq!(c.stat(np, "/f").unwrap().size, 32 * 4096);
    let fd2 = c.open(np, "/f").unwrap();
    assert_eq!(c.pread(np, fd2, 0, 32 * 4096).unwrap().len(), 32 * 4096);
    assert!(!c.fault_stats.detection_latency.is_empty());
}

// ================================================== stragglers

#[test]
fn straggler_replica_demoted_but_chain_still_serves() {
    let (mut c, w, _fd) = seeded_cluster();
    // node 1's NVM runs 10x slow — degraded, not dead
    c.straggle_nvm(1, 10).unwrap();
    let r = c.spawn_process(3, 0);
    c.set_now(r, c.now(w) + 1_000_000);
    let f = c.open(r, "/v").unwrap();
    for k in 0..8u64 {
        c.set_now(r, c.now(r) + k * 1_000_000);
        assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 1);
    }
    assert_eq!(c.reads_served_by[1], 0, "straggler must not serve while peers can");
    assert!(c.fault_stats.straggler_reads_rerouted > 0);

    // healing the device restores the replica to normal ranking
    c.straggle_nvm(1, 1).unwrap();
    assert!(!c.mgr.is_straggler(1));
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 1);
}

#[test]
fn nic_straggler_flags_node_and_inflates_rpc() {
    let (mut c, _w, _fd) = seeded_cluster();
    c.straggle_nic(2, 8).unwrap();
    assert!(c.mgr.is_straggler(2));
    assert_eq!(c.fault.nic_mult(2), 8);
    c.straggle_nic(2, 1).unwrap();
    assert!(!c.mgr.is_straggler(2));
}

// ================================================== drop / reorder

#[test]
fn drop_budget_exhaustion_surfaces_chain_unavailable() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/f").unwrap();
    c.set_drop_plan(1.0, 0.0, 2, 1_000, 0); // every send drops
    c.pwrite(w, fd, 0, Payload::zero(4096)).unwrap(); // local append fine
    let res = c.fsync(w, fd);
    assert!(
        matches!(res, Err(FsError::ChainUnavailable(_))),
        "retry budget exhaustion must refuse, got {res:?}"
    );
    assert!(c.fault_stats.messages_dropped >= 3, "initial try + 2 retries all dropped");
    assert!(c.fault_stats.partitioned_sends_refused >= 1);
}

#[test]
fn lossy_link_with_retry_budget_still_acks_everything() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
    c.fault = FaultPlan::new(11);
    c.set_drop_plan(0.25, 0.10, 30, 1_000, 5_000);
    let w = c.spawn_process(0, 0);
    let fd = c.create(w, "/f").unwrap();
    for k in 0..24u64 {
        c.pwrite(w, fd, k * 4096, Payload::zero(4096)).unwrap();
        c.fsync(w, fd).unwrap(); // retries absorb every drop
    }
    assert!(c.fault_stats.messages_dropped > 0, "a 25% drop plan must have fired");
    c.digest_log(w).unwrap();
    assert_eq!(c.stat(w, "/f").unwrap().size, 24 * 4096, "acked writes all durable");
}

#[test]
fn same_seed_replays_identical_virtual_time_trace() {
    fn run(seed: u64) -> (u64, u64, u64, u64) {
        let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
        c.fault = FaultPlan::new(seed);
        c.set_drop_plan(0.15, 0.10, 20, 1_000, 5_000);
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        let mut rng = SplitMix64::new(77);
        for _ in 0..40 {
            c.pwrite(pid, fd, rng.below(64) * 4096, Payload::zero(4096)).unwrap();
            if rng.below(4) == 0 {
                c.fsync(pid, fd).unwrap();
            }
        }
        c.fsync(pid, fd).unwrap();
        (
            c.now(pid),
            c.fault_stats.messages_dropped,
            c.fault_stats.messages_reordered,
            c.fault_stats.partitioned_sends_refused,
        )
    }
    let a = run(42);
    let b = run(42);
    assert_eq!(a, b, "same fault seed must replay an identical trace");
    assert!(a.1 > 0, "the drop plan must actually have perturbed the run");
}

// ================================================== flapping

#[test]
fn flap_within_suspicion_window_is_absorbed() {
    let (mut c, w, _fd) = seeded_cluster();
    let t = c.now(w);
    // outage shorter than heartbeat + suspect: the first missed beat
    // only starts the suspicion timer — the node is never declared dead
    let short = c.cfg.heartbeat_interval / 2;
    assert_eq!(c.flap_node(1, t, t + short).unwrap(), None);
    assert!(c.mgr.is_up(1), "absorbed flap must not declare the node down");
    assert!(c.nodes[1].alive);

    // outage past the window is a real failure: declared, then recovered
    let long = 2 * (c.cfg.heartbeat_interval + c.cfg.suspect_timeout);
    let detected = c.flap_node(1, t, t + long).unwrap();
    assert_eq!(detected, Some(t + c.cfg.heartbeat_interval + c.cfg.suspect_timeout));
    assert!(c.mgr.is_up(1), "flapped node rejoins after recovery");
}

#[test]
fn scheduled_flaps_run_in_order_and_reads_survive() {
    let (mut c, w, _fd) = seeded_cluster();
    let t = c.now(w);
    let window = c.cfg.heartbeat_interval + c.cfg.suspect_timeout;
    // one absorbed blip, one real outage, scheduled out of order
    c.fault.schedule_flap(2, t + 10 * window, t + 13 * window);
    c.fault.schedule_flap(1, t + 2 * window, t + 2 * window + window / 4);
    let outcomes = c.run_flap_schedule().unwrap();
    assert_eq!(outcomes.len(), 2);
    assert_eq!(outcomes[0], (1, None), "short blip absorbed");
    assert_eq!(outcomes[1].0, 2);
    assert!(outcomes[1].1.is_some(), "long outage declared");

    // after the dust settles every replica serves the committed version
    let r = c.spawn_process(3, 0);
    c.set_now(r, t + 20 * window);
    let f = c.open(r, "/v").unwrap();
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 1);
}

// ================================================== clock skew

#[test]
fn lease_exclusivity_survives_clock_skew() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    let a = c.spawn_process(0, 0);
    let b = c.spawn_process(1, 0);
    let fda = c.create(a, "/shared").unwrap();
    c.pwrite(a, fda, 0, Payload::bytes(encode(1))).unwrap();
    c.fsync(a, fda).unwrap();
    c.digest_log(a).unwrap();

    // b's clock runs 2 s ahead of the cluster; a fast clock must not
    // let it treat an unexpired remote lease as expired
    c.skew_clock(b, 2_000_000_000).unwrap();
    assert_eq!(c.fault.skew_of(b), 2_000_000_000);
    let fdb = c.open(b, "/shared").unwrap();
    c.pwrite(b, fdb, 0, Payload::bytes(encode(2))).unwrap();
    c.fsync(b, fdb).unwrap();
    let now = c.now(a).max(c.now(b));
    assert!(c.lease_exclusivity_ok(now), "overlapping write leases under +skew");

    // a drifts backwards; reclaiming the lease must stay exclusive too
    c.skew_clock(a, -500_000_000).unwrap();
    c.set_now(a, c.now(a).max(c.now(b)));
    c.pwrite(a, fda, 0, Payload::bytes(encode(3))).unwrap();
    c.fsync(a, fda).unwrap();
    let now = c.now(a).max(c.now(b));
    assert!(c.lease_exclusivity_ok(now), "overlapping write leases under -skew");

    // and the last write wins: no torn/stale payload on either side
    c.digest_log(a).unwrap();
    let r = c.spawn_process(1, 0);
    c.set_now(r, now + 1_000_000);
    let f = c.open(r, "/shared").unwrap();
    assert_eq!(decode(&c.pread(r, f, 0, 8).unwrap().materialize()), 3);
}

// ================================================== randomized scripts

/// The CRAQ property script from `craq_reads.rs`, re-run under a rotating
/// fault mix: a straggler NVM, a straggler NIC, and a lossy (but
/// retry-covered) fabric. The read invariants may not weaken under any
/// of them.
#[test]
fn prop_read_invariants_hold_under_fault_mix() {
    for seed in 0..6u64 {
        let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
        c.fault = FaultPlan::new(500 + seed);
        match seed % 3 {
            0 => c.straggle_nvm(1, 10).unwrap(),
            1 => c.straggle_nic(2, 6).unwrap(),
            _ => c.set_drop_plan(0.15, 0.05, 30, 1_000, 5_000),
        }
        let mut rng = SplitMix64::new(7000 + seed);
        let w = c.spawn_process(0, 0);
        let fd = c.create(w, "/v").unwrap();
        c.pwrite(w, fd, 0, Payload::bytes(encode(1))).unwrap();
        c.fsync(w, fd).unwrap();
        c.digest_log(w).unwrap();

        let readers = [c.spawn_process(0, 0), c.spawn_process(1, 0), c.spawn_process(2, 0)];
        let mut rfds = Vec::new();
        for &r in readers.iter() {
            c.set_now(r, c.now(w));
            rfds.push(c.open(r, "/v").unwrap());
        }

        let mut latest = 1u64;
        let mut committed = 1u64;
        let mut last_seen = [1u64; 3];
        for _ in 0..50 {
            match rng.below(4) {
                0 => {
                    latest += 1;
                    c.pwrite(w, fd, 0, Payload::bytes(encode(latest))).unwrap();
                }
                1 => {
                    c.fsync(w, fd).unwrap();
                }
                2 => {
                    c.fsync(w, fd).unwrap();
                    c.digest_log(w).unwrap();
                    committed = latest;
                }
                _ => {
                    let i = rng.below(3) as usize;
                    let r = readers[i];
                    c.set_now(r, c.now(r).max(c.now(w)));
                    let got = decode(&c.pread(r, rfds[i], 0, 8).unwrap().materialize());
                    assert!(got >= committed, "seed {seed}: stale read {got} < {committed}");
                    assert!(got <= latest, "seed {seed}: torn read {got} > {latest}");
                    assert!(got >= last_seen[i], "seed {seed}: reader {i} went backwards");
                    last_seen[i] = got;
                }
            }
        }
        let own = decode(&c.pread(w, fd, 0, 8).unwrap().materialize());
        assert_eq!(own, latest, "seed {seed}: writer must read its own write");
    }
}

// ================================================== resize-log 2PC

#[test]
fn partitioned_2pc_participant_vetoes_resize() {
    use assise::oplog::ResizeOutcome;
    let (mut c, w, _fd) = seeded_cluster();
    let old = c.procs[w].log.capacity();

    // cut the coordinator (node 0) off from chain replica 2: the PREPARE
    // hop must be refused by the fault layer and become a Deny vote —
    // never costed as a reachable round trip
    c.partition(0, 2).unwrap();
    let refused_before = c.fault_stats.partitioned_sends_refused;
    match c.resize_log(w, old * 2) {
        ResizeOutcome::Aborted { denier, .. } => assert_eq!(denier, 2),
        o => panic!("partitioned participant must veto the resize, got {o:?}"),
    }
    assert!(
        c.fault_stats.partitioned_sends_refused > refused_before,
        "the refused PREPARE hop must be visible in the fault counters"
    );
    assert_eq!(c.procs[w].log.capacity(), old, "abort keeps the old size");

    // heal: the same resize commits (the aborted round released its
    // phase-1 reservations on the Accept voters)
    c.heal_all_partitions();
    match c.resize_log(w, old * 2) {
        ResizeOutcome::Committed { new_size, .. } => assert_eq!(new_size, old * 2),
        o => panic!("healed resize must commit, got {o:?}"),
    }
}

// ================================================== bad ids

#[test]
fn bad_ids_surface_invalid_argument_not_panics() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    let pid = c.spawn_process(0, 0);
    c.create(pid, "/f").unwrap();
    assert!(matches!(c.kill_node(99, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.kill_process(99), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.restart_process(99, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.failover_process(99, 0, 0, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.failover_process(pid, 99, 0, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.recover_node(99, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.os_failover(99, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.partition(0, 99), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.partition_oneway(99, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.isolate_node(99), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.straggle_nvm(99, 10), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.straggle_nic(99, 10), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.skew_clock(99, 5), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.flap_node(99, 0, 1), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.flap_node(0, 10, 5), Err(FsError::InvalidArgument(_))));
    assert!(matches!(c.suspect_partitioned_node(99, 0), Err(FsError::InvalidArgument(_))));
    assert!(matches!(
        c.migrate_chain("/f", vec![99], vec![], 0),
        Err(FsError::InvalidArgument(_))
    ));
    // the cluster is untouched: the real node still serves
    assert!(c.stat(pid, "/f").is_ok());
}
