//! Property tests over CC-NVM's invariants (DESIGN.md list), using an
//! in-crate seeded harness (SplitMix64 op-sequence generators swept over
//! many seeds — the offline build environment has no proptest crate; the
//! adversarial coverage style is the same).

use assise::coherence::lease::{Acquire, LeaseMode, LeaseTable};
use assise::fs::{FileStore, Payload, Tier};
use assise::oplog::{apply_entries, coalesce, LogEntry, LogOp};
use assise::sim::{Cluster, ClusterConfig, CrashMode, DistFs};
use assise::util::SplitMix64;

const SEEDS: u64 = 40;

// ------------------------------------------------------------ generators

fn gen_ops(rng: &mut SplitMix64, n: usize) -> Vec<LogOp> {
    use assise::fs::{Cred, Mode};
    let mut live: Vec<String> = Vec::new();
    let mut out = Vec::new();
    let mut uniq = 0;
    for _ in 0..n {
        let pick = rng.below(100);
        match pick {
            0..=29 => {
                let path = format!("/f{uniq}");
                uniq += 1;
                live.push(path.clone());
                out.push(LogOp::Create { path, mode: Mode::DEFAULT_FILE, owner: Cred::ROOT });
            }
            30..=74 if !live.is_empty() => {
                let path = live[rng.below(live.len() as u64) as usize].clone();
                let off = rng.below(4096);
                let len = 1 + rng.below(4096);
                out.push(LogOp::Write { path, off, data: Payload::synthetic(rng.next_u64(), len) });
            }
            75..=84 if !live.is_empty() => {
                let i = rng.below(live.len() as u64) as usize;
                let from = live.remove(i);
                let to = format!("/r{uniq}");
                uniq += 1;
                live.push(to.clone());
                out.push(LogOp::Rename { from, to });
            }
            85..=92 if !live.is_empty() => {
                let path = live[rng.below(live.len() as u64) as usize].clone();
                let size = rng.below(2048);
                out.push(LogOp::Truncate { path, size });
            }
            _ if !live.is_empty() => {
                let i = rng.below(live.len() as u64) as usize;
                let path = live.remove(i);
                out.push(LogOp::Unlink { path });
            }
            _ => {}
        }
    }
    out
}

fn entries(ops: Vec<LogOp>) -> Vec<LogEntry> {
    ops.into_iter()
        .enumerate()
        .map(|(i, op)| LogEntry { seq: i as u64 + 1, op })
        .collect()
}

// ------------------------------------------------------------ properties

/// Digest replay from ANY crash point converges to the clean state.
#[test]
fn prop_digest_idempotent_from_any_crash_point() {
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(seed);
        let batch = entries(gen_ops(&mut rng, 30));
        let mut clean = FileStore::new();
        apply_entries(&mut clean, &batch, 0, Tier::Hot, 1).unwrap();

        // crash after k entries, replay everything
        for k in [0, 1, batch.len() / 2, batch.len().saturating_sub(1)] {
            let mut crashed = FileStore::new();
            apply_entries(&mut crashed, &batch[..k], 0, Tier::Hot, 1).unwrap();
            apply_entries(&mut crashed, &batch, 0, Tier::Hot, 2).unwrap();
            assert!(
                crashed.content_eq(&clean),
                "seed {seed} crash-at {k}: replay diverged"
            );
        }
    }
}

/// Coalescing preserves the batch's final state.
#[test]
fn prop_coalesce_preserves_final_state() {
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(1000 + seed);
        let batch = entries(gen_ops(&mut rng, 40));
        let mut full = FileStore::new();
        apply_entries(&mut full, &batch, 0, Tier::Hot, 1).unwrap();

        let c = coalesce(&batch);
        let mut reduced = FileStore::new();
        apply_entries(&mut reduced, &c.entries, 0, Tier::Hot, 1).unwrap();
        assert!(
            reduced.content_eq(&full),
            "seed {seed}: coalesced batch diverged (saved {} bytes)",
            c.saved_bytes
        );
    }
}

/// Lease tables never grant overlapping write access to two holders.
#[test]
fn prop_lease_exclusivity_under_random_ops() {
    for seed in 0..SEEDS {
        let mut rng = SplitMix64::new(2000 + seed);
        let mut t = LeaseTable::new();
        let paths = ["/a", "/a/b", "/a/b/c", "/d", "/d/e", "/f"];
        for step in 0..200u64 {
            let holder = rng.below(4) as usize;
            let path = paths[rng.below(paths.len() as u64) as usize];
            let mode = if rng.f64() < 0.5 { LeaseMode::Read } else { LeaseMode::Write };
            let now = step * 1000;
            match t.acquire(path, mode, holder, now, 50_000) {
                Acquire::Granted => {}
                Acquire::MustRevoke(hs) => {
                    for h in hs {
                        t.revoke(path, h);
                    }
                    assert_eq!(t.acquire(path, mode, holder, now, 50_000), Acquire::Granted);
                }
            }
            assert!(t.check_exclusivity(now), "seed {seed} step {step}");
        }
    }
}

/// After every fsync+digest, all chain replicas hold identical state.
#[test]
fn prop_chain_agreement_after_digest() {
    for seed in 0..12 {
        let mut rng = SplitMix64::new(3000 + seed);
        let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
        let pid = c.spawn_process(0, 0);
        c.mkdir(pid, "/w").unwrap();
        let mut files: Vec<(String, u32)> = Vec::new();
        for i in 0..rng.below(20) + 5 {
            let path = format!("/w/f{i}");
            let fd = c.create(pid, &path).unwrap();
            let writes = 1 + rng.below(5);
            for _ in 0..writes {
                let off = rng.below(8192);
                let len = 1 + rng.below(4096);
                c.pwrite(pid, fd, off, Payload::synthetic(rng.next_u64(), len)).unwrap();
            }
            files.push((path, fd));
        }
        c.replicate_log(pid).unwrap();
        c.digest_log(pid).unwrap();
        let a = &c.nodes[0].sockets[0].sharedfs.store;
        let b = &c.nodes[1].sockets[0].sharedfs.store;
        let d = &c.nodes[2].sockets[0].sharedfs.store;
        assert!(a.content_eq(b), "seed {seed}: replica 0 != 1");
        assert!(b.content_eq(d), "seed {seed}: replica 1 != 2");
    }
}

/// Whatever interleaving of writers, a reader through the API observes
/// the latest fsync'd content (linearizability via leases).
#[test]
fn prop_reader_sees_latest_write() {
    for seed in 0..12 {
        let mut rng = SplitMix64::new(4000 + seed);
        let mut c = Cluster::new(ClusterConfig::default().nodes(2));
        let writers = [c.spawn_process(0, 0), c.spawn_process(1, 0)];
        let setup = writers[0];
        c.mkdir(setup, "/s").unwrap();
        let fd0 = c.create(setup, "/s/f").unwrap();
        c.write(setup, fd0, Payload::bytes(vec![0xFF; 64])).unwrap();

        let mut latest = vec![0xFFu8; 64];
        for round in 0..10 {
            let w = writers[rng.below(2) as usize];
            // keep clocks loosely in sync so leases can transfer
            let t = writers.iter().map(|&p| c.now(p)).max().unwrap();
            c.set_now(w, t);
            let fd = c.open(w, "/s/f").unwrap();
            let val = vec![round as u8; 64];
            c.pwrite(w, fd, 0, Payload::bytes(val.clone())).unwrap();
            latest = val;
            c.close(w, fd).unwrap();

            let r = writers[rng.below(2) as usize];
            let t = writers.iter().map(|&p| c.now(p)).max().unwrap();
            c.set_now(r, t);
            let fd = c.open(r, "/s/f").unwrap();
            let got = c.pread(r, fd, 0, 64).unwrap().materialize();
            assert_eq!(got, latest, "seed {seed} round {round}");
            c.close(r, fd).unwrap();
        }
    }
}

/// Prefix property under random fsync/crash points: recovered state on
/// the backup equals replaying exactly the fsync'd prefix.
#[test]
fn prop_failover_recovers_exact_prefix() {
    for seed in 0..16 {
        let mut rng = SplitMix64::new(5000 + seed);
        let mut c = Cluster::new(ClusterConfig::default().nodes(2));
        let pid = c.spawn_process(0, 0);
        let fd = c.create(pid, "/f").unwrap();
        let total = 10 + rng.below(20);
        let fsync_at = rng.below(total) + 1;
        let mut fsynced_len = 0u64;
        let mut len = 0u64;
        for i in 0..total {
            let chunk = 10 + rng.below(100);
            c.pwrite(pid, fd, len, Payload::synthetic(i, chunk)).unwrap();
            len += chunk;
            if i + 1 == fsync_at {
                c.fsync(pid, fd).unwrap();
                fsynced_len = len;
            }
        }
        let t = c.now(pid);
        c.kill_node(0, t).unwrap();
        let (np, _) = c.failover_process(pid, 1, 0, t).unwrap();
        let st = c.stat(np, "/f").unwrap();
        assert_eq!(st.size, fsynced_len, "seed {seed}: backup size != fsync'd prefix");
    }
}

/// Local process restart recovers *everything*, in both modes.
#[test]
fn prop_local_restart_total_recovery() {
    for seed in 0..16 {
        for mode in [CrashMode::Pessimistic, CrashMode::Optimistic] {
            let mut rng = SplitMix64::new(6000 + seed);
            let mut c = Cluster::new(ClusterConfig::default().nodes(2).mode(mode));
            let pid = c.spawn_process(0, 0);
            let fd = c.create(pid, "/f").unwrap();
            let mut len = 0u64;
            for i in 0..5 + rng.below(10) {
                let chunk = 1 + rng.below(200);
                c.pwrite(pid, fd, len, Payload::synthetic(i, chunk)).unwrap();
                len += chunk;
            }
            let t = c.now(pid);
            c.kill_process(pid).unwrap();
            c.restart_process(pid, t).unwrap();
            let fd2 = c.open(pid, "/f").unwrap();
            let st = c.stat(pid, "/f").unwrap();
            assert_eq!(st.size, len, "seed {seed} mode {mode:?}");
            let _ = c.pread(pid, fd2, 0, len).unwrap();
        }
    }
}

/// Hard backpressure: whatever the write pattern, the update log never
/// exceeds its capacity once a write has returned — the write path must
/// stall on (and drain) outstanding digests instead of overflowing NVM.
#[test]
fn prop_log_never_exceeds_capacity_after_write() {
    for seed in 0..16 {
        let mut rng = SplitMix64::new(7000 + seed);
        // tiny log: a handful of writes trips both the background-digest
        // threshold and the hard-backpressure loop
        let cap = 16 << 10;
        let mut c = Cluster::new(
            ClusterConfig::default().nodes(3).log_capacity(cap).repl_window(2),
        );
        // sharded subtrees so backpressure drains PARTITIONED batches too
        c.set_subtree_chain("/a", vec![1], vec![]).unwrap();
        c.set_subtree_chain("/b", vec![2], vec![]).unwrap();
        let pid = c.spawn_process(0, 0);
        c.mkdir(pid, "/a").unwrap();
        c.mkdir(pid, "/b").unwrap();
        let fa = c.create(pid, "/a/f").unwrap();
        let fb = c.create(pid, "/b/f").unwrap();
        let mut off = 0u64;
        for i in 0..60 {
            let fd = if rng.f64() < 0.5 { fa } else { fb };
            let len = 1 + rng.below(6000); // entries up to ~40% of the log
            c.pwrite(pid, fd, off, Payload::synthetic(i, len)).unwrap();
            off += len;
            assert!(
                c.procs[pid].log.used() <= cap,
                "seed {seed} write {i}: log {} > capacity {cap}",
                c.procs[pid].log.used()
            );
            if rng.f64() < 0.2 {
                c.fsync(pid, fd).unwrap();
                assert!(c.procs[pid].log.used() <= cap, "seed {seed} post-fsync overflow");
            }
        }
    }
}

/// The `guard > 64` escape hatch: a log smaller than a single entry
/// cannot hold the capacity invariant, but writes must still return
/// (not spin) and the log must drain to at most the one oversized entry.
#[test]
fn prop_log_smaller_than_one_entry_escape_hatch() {
    // capacity below ENTRY_HEADER_BYTES + payload: the invariant is
    // unsatisfiable by construction
    let mut c = Cluster::new(ClusterConfig::default().nodes(2).log_capacity(512));
    let pid = c.spawn_process(0, 0);
    let fd = c.create(pid, "/f").unwrap();
    for i in 0..8u64 {
        // each entry is 256 B header + 4 KB payload > 512 B capacity
        c.pwrite(pid, fd, i * 4096, Payload::synthetic(i, 4096)).unwrap();
        // the oversized entry is digested+reclaimed synchronously, so the
        // log holds at most the entry appended by THIS write
        assert!(
            c.procs[pid].log.len() <= 1,
            "write {i}: {} entries linger in an undersized log",
            c.procs[pid].log.len()
        );
    }
    // contents stay correct end to end
    let got = c.pread(pid, fd, 0, 8 * 4096).unwrap();
    assert_eq!(got.len(), 8 * 4096);
}
