//! Quickstart: the Assise public API in ~60 lines.
//!
//! Builds a 2-node cluster, writes through the POSIX-style API, shows
//! the latency difference between a local NVM write and a replicated
//! fsync, digests, and survives a node failure.
//!
//! Run: `cargo run --release --example quickstart`

use assise::fs::Payload;
use assise::sim::{Cluster, ClusterConfig, CrashMode, DistFs};

fn main() {
    // ---- 1. a 2-node cluster, pessimistic mode (fsync = replication)
    let mut cluster = Cluster::new(ClusterConfig::default().nodes(2));
    let pid = cluster.spawn_process(0, 0); // node 0, socket 0

    // ---- 2. POSIX-style IO (function calls into LibFS: kernel bypass)
    cluster.mkdir(pid, "/data").unwrap();
    let fd = cluster.create(pid, "/data/hello").unwrap();
    cluster.write(pid, fd, Payload::bytes(b"written to colocated NVM".to_vec())).unwrap();
    println!("write    : {:>8} ns  (process-local NVM update log)", cluster.last_latency(pid));

    cluster.fsync(pid, fd).unwrap();
    println!("fsync    : {:>8} ns  (chain replication over RDMA)", cluster.last_latency(pid));

    let back = cluster.pread(pid, fd, 0, 24).unwrap();
    println!("read     : {:>8} ns  (log-view hit)", cluster.last_latency(pid));
    assert_eq!(back.materialize(), b"written to colocated NVM");

    // ---- 3. digest: move the log into the SharedFS second-level cache
    cluster.digest_log(pid).unwrap();
    let again = cluster.pread(pid, fd, 0, 24).unwrap();
    println!("read     : {:>8} ns  (SharedFS hot area after digest)", cluster.last_latency(pid));
    assert_eq!(again.materialize(), b"written to colocated NVM");

    // ---- 4. node failure: fail over to the cache replica
    let t = cluster.now(pid);
    cluster.kill_node(0, t).unwrap();
    let (np, report) = cluster.failover_process(pid, 1, 0, t).unwrap();
    println!(
        "failover : detection {} ms (heartbeat), recovery work {} us",
        (report.detected_at - report.failed_at) / 1_000_000,
        (report.first_op_at - report.detected_at) / 1_000
    );
    let fd2 = cluster.open(np, "/data/hello").unwrap();
    assert_eq!(cluster.pread(np, fd2, 0, 24).unwrap().materialize(), b"written to colocated NVM");
    println!("data intact on the backup replica");

    // ---- 5. optimistic mode: cheap fsync, dsync when you mean it
    let mut opt = Cluster::new(ClusterConfig::default().nodes(2).mode(CrashMode::Optimistic));
    let p = opt.spawn_process(0, 0);
    let f = opt.create(p, "/log").unwrap();
    opt.write(p, f, Payload::bytes(vec![0u8; 4096])).unwrap();
    opt.fsync(p, f).unwrap(); // ordering only — near-free
    println!("opt fsync: {:>8} ns  (ordering only; dsync forces replication)", opt.last_latency(p));
    opt.dsync(p, f).unwrap();
    println!("dsync    : {:>8} ns", opt.last_latency(p));

    println!("quickstart OK");
}
