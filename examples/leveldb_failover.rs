//! LevelDB fail-over walkthrough (paper §5.4 / Fig. 7): run an LSM KV
//! store on the primary, kill the node, fail over to the hot backup,
//! recover the primary, and print the timeline.
//!
//! Run: `cargo run --release --example leveldb_failover`

use assise::sim::{Cluster, ClusterConfig, DistFs};
use assise::util::SplitMix64;
use assise::workloads::{KvConfig, KvStore};

fn main() {
    let mut c = Cluster::new(ClusterConfig::default().nodes(2));
    let pid = c.spawn_process(0, 0);
    let cfg = KvConfig { memtable_bytes: 1 << 20, value_size: 4096, ..Default::default() };
    let mut kv = KvStore::create(&mut c, pid, cfg.clone()).unwrap();
    let mut rng = SplitMix64::new(1);

    // steady state: 1:1 read/write
    let n = 5_000u64;
    for i in 0..n {
        if i % 2 == 0 {
            kv.put(&mut c, rng.below(n), false).unwrap();
        } else {
            kv.get(&mut c, rng.below(n)).unwrap();
        }
    }
    c.replicate_log(pid).unwrap();
    println!("steady state: {} SSTs, {} flushes, dataset {} MB", kv.sst_count(), kv.flushes, kv.dataset_bytes() >> 20);

    // kill the primary
    let t_fail = c.now(pid);
    c.kill_node(0, t_fail).unwrap();
    let (np, report) = c.failover_process(pid, 1, 0, t_fail).unwrap();
    println!(
        "primary killed @ {:.2}s | detected +{} ms | backup evicted log +{} us",
        t_fail as f64 / 1e9,
        (report.detected_at - report.failed_at) / 1_000_000,
        (report.first_op_at - report.detected_at) / 1_000
    );

    // LevelDB restart on the backup: integrity check then serve
    let (manifest, wal) = kv.manifest();
    let t0 = c.now(np);
    let mut kv2 = KvStore::reopen(&mut c, np, cfg.clone(), manifest, wal).unwrap();
    println!("leveldb integrity check: {} ms", (c.now(np) - t0) / 1_000_000);
    let (found, lat) = kv2.get(&mut c, 42).unwrap();
    println!("first read on backup: found={found} in {} us", lat / 1_000);

    // primary recovery
    let t_rec = c.now(np) + 30_000_000_000;
    let done = c.recover_node(0, t_rec).unwrap();
    println!(
        "primary rejoined after 30 s: epoch bitmaps fetched in {} us, {} stale inodes to refetch lazily",
        (done - t_rec) / 1_000,
        c.stale_inodes(0)
    );
    let p3 = c.spawn_process(0, 0);
    c.set_now(p3, done);
    let (manifest, wal) = kv2.manifest();
    let t0 = c.now(p3);
    let mut kv3 = KvStore::reopen(&mut c, p3, cfg, manifest, wal).unwrap();
    println!("restart on recovered primary: {} ms", (c.now(p3) - t0) / 1_000_000);
    let (found, _) = kv3.get(&mut c, 42).unwrap();
    assert!(found);
    println!("failover walkthrough OK");
}
