//! End-to-end driver: distributed MinuteSort (Tencent Sort, Table 3)
//! through the full three-layer stack on a real workload.
//!
//! - L3 (this binary + the Assise cluster): distributes input over 4
//!   nodes, runs the two sort phases through the POSIX API with chain
//!   metadata, reports the Table 3 breakdown in virtual time;
//! - L1/L2 (AOT Pallas → PJRT): the range-partition kernel computes
//!   every record's destination bucket — loaded from
//!   `artifacts/partition.hlo.txt` and executed natively (Python is not
//!   running);
//! - validation: the output partitions are REAL sorted bytes, checked
//!   for global order and completeness (the paper runs valsort).
//!
//! Run: `make artifacts && cargo run --release --example minutesort`
// Bench harnesses are the sanctioned wall-clock users (see clippy.toml's
// disallowed-methods and the assise-lint determinism rule).
#![allow(clippy::disallowed_methods)]
use assise::baselines::NfsLike;
use assise::runtime::PartitionExec;
use assise::sim::{Cluster, ClusterConfig, DistFs};
use assise::workloads::sort::SortJob;

fn main() {
    let records_per_worker = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000usize);
    let workers_n = 16;

    let partition = match PartitionExec::load() {
        Ok(p) => {
            println!(
                "L1 partition kernel loaded ({} backend)",
                assise::runtime::backend_name()
            );
            Some(p)
        }
        Err(e) => {
            eprintln!("WARNING: partition kernel unavailable ({e}); falling back to rust ref");
            None
        }
    };

    // ---- Assise
    let mut c = Cluster::new(ClusterConfig::default().nodes(4).replication(1));
    let workers: Vec<_> = (0..workers_n).map(|w| c.spawn_process(w % 4, 0)).collect();
    let job = SortJob {
        workers,
        records_per_worker,
        use_kernel: partition.is_some(),
        batched: false,
    };
    let wall = std::time::Instant::now();
    let (t, count) = job.run(&mut c, partition.as_ref()).expect("sort failed");
    println!(
        "assise : {} records sorted & validated | partition {:.3}s sort {:.3}s total {:.3}s (virtual) | {:.1}s wall",
        count,
        t.partition_ns as f64 / 1e9,
        t.sort_ns as f64 / 1e9,
        t.total_ns() as f64 / 1e9,
        wall.elapsed().as_secs_f64()
    );

    // ---- Assise, batched submission (io_uring-style driver)
    let mut cb = Cluster::new(ClusterConfig::default().nodes(4).replication(1));
    let workers: Vec<_> = (0..workers_n).map(|w| cb.spawn_process(w % 4, 0)).collect();
    let job = SortJob {
        workers,
        records_per_worker,
        use_kernel: partition.is_some(),
        batched: true,
    };
    let (tb, count_b) = job.run(&mut cb, partition.as_ref()).expect("batched sort failed");
    println!(
        "assise (batched submit): {} records | partition {:.3}s sort {:.3}s total {:.3}s (virtual)",
        count_b,
        tb.partition_ns as f64 / 1e9,
        tb.sort_ns as f64 / 1e9,
        tb.total_ns() as f64 / 1e9,
    );

    // ---- NFS comparison (per-machine mounts, the paper's baseline)
    let mut n = NfsLike::new(4, 3 << 30, Default::default());
    let workers: Vec<_> = (0..workers_n).map(|w| n.spawn_process(w % 4, 0)).collect();
    let job = SortJob { workers, records_per_worker, use_kernel: false, batched: false };
    let (tn, count_n) = job.run(&mut n, None).expect("nfs sort failed");
    println!(
        "nfs    : {} records | partition {:.3}s sort {:.3}s total {:.3}s (virtual)",
        count_n,
        tn.partition_ns as f64 / 1e9,
        tn.sort_ns as f64 / 1e9,
        tn.total_ns() as f64 / 1e9,
    );
    let speedup = tn.total_ns() as f64 / t.total_ns() as f64;
    println!("assise is {speedup:.2}x faster end-to-end (paper: up to 2.2x)");
    assert_eq!(count, count_n);
    assert!(speedup > 1.0, "assise must beat NFS");
}
