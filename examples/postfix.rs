//! Postfix-style mail delivery (paper §5.5.2 / Fig. 9): compare Maildir
//! sharding policies on a 3-replica Assise cluster.
//!
//! Run: `cargo run --release --example postfix [mails]`

use assise::sim::{Cluster, ClusterConfig, DistFs};
use assise::workloads::mail::{maildir_for, EnronLike, MailSim, Sharding};

fn main() {
    let mails = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(500usize);
    let users = 60;
    let cliques = 6;
    let procs = 6;

    for policy in [Sharding::RoundRobin, Sharding::Clique, Sharding::Private] {
        let mut c = Cluster::new(ClusterConfig::default().nodes(3).replication(3));
        let pids: Vec<_> = (0..procs).map(|i| c.spawn_process(i % 3, 0)).collect();
        let mut workers: Vec<MailSim> = pids.iter().map(|&p| MailSim::new(p, p % 3)).collect();
        for w in &mut workers {
            w.setup(&mut c).unwrap();
        }
        match policy {
            Sharding::Private => {
                for &pid in &pids {
                    c.mkdir(pid, &format!("/maildir-p{pid}")).unwrap();
                    for u in 0..users {
                        c.mkdir(pid, &format!("/maildir-p{pid}/u{u}")).unwrap();
                    }
                }
            }
            _ => {
                c.mkdir(pids[0], "/maildir").unwrap();
                for u in 0..users {
                    c.mkdir(pids[0], &format!("/maildir/u{u}")).unwrap();
                }
            }
        }
        let mut corpus = EnronLike::new(users, cliques, 3);
        let start: Vec<u64> = pids.iter().map(|&p| c.now(p)).collect();
        let mut delivered = 0u64;
        for m in 0..mails {
            let (rcpts, size) = corpus.next_mail();
            for &user in &rcpts {
                let clique = corpus.clique_of(user);
                let w = match policy {
                    Sharding::Clique => (0..procs).find(|i| i % 3 == clique % 3).unwrap_or(m % procs),
                    _ => m % procs,
                };
                let dir = maildir_for(policy, user, clique, pids[w]);
                workers[w].deliver(&mut c, &dir, size, m as u64).unwrap();
                delivered += 1;
            }
        }
        let elapsed = pids.iter().enumerate().map(|(i, &p)| c.now(p) - start[i]).max().unwrap();
        println!(
            "{:<12} {:>6} deliveries in {:>8.1} ms virtual -> {:>8.0} deliveries/s",
            format!("{policy:?}"),
            delivered,
            elapsed as f64 / 1e6,
            delivered as f64 * 1e9 / elapsed as f64
        );
    }
    println!("postfix example OK (paper: private ≈ sharded > round-robin)");
}
