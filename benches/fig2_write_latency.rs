//! `cargo bench` wrapper regenerating the paper's fig2a.
//! Scale via `ASSISE_BENCH_SCALE` (default 0.2 to keep bench runs quick;
//! use `assise bench fig2a --scale 1` for the full run).
// Bench harnesses are the sanctioned wall-clock users (see clippy.toml's
// disallowed-methods and the assise-lint determinism rule).
#![allow(clippy::disallowed_methods)]
fn main() {
    let scale = std::env::var("ASSISE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let wall = std::time::Instant::now();
    for t in assise::bench::run("fig2a", assise::bench::Scale(scale)).expect("known experiment") {
        t.print();
    }
    eprintln!("[fig2_write_latency] wall-clock: {:.1}s at scale {scale}", wall.elapsed().as_secs_f64());
}
