"""pytest: L2 model shapes + AOT lowering round-trip.

Verifies the exact graphs the rust runtime will execute: jit(fn) evaluated
in-process must match the oracle, and the lowered HLO text must parse and
re-execute (via jax's own runtime) to identical results.
"""

from __future__ import annotations

import numpy as np
import jax

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import ref


class TestModel:
    def test_digest_verify_shapes(self):
        rng = np.random.default_rng(0)
        w = rng.integers(
            0, 1 << 32, size=(model.CHECKSUM_BLOCKS, model.CHECKSUM_WORDS),
            dtype=np.uint64,
        ).astype(np.uint32)
        (out,) = model.digest_verify(w)
        assert out.shape == (model.CHECKSUM_BLOCKS, 2)
        np.testing.assert_array_equal(np.asarray(out), ref.checksum_ref_vec(w))

    def test_sort_partition_shapes(self):
        rng = np.random.default_rng(1)
        k = rng.integers(0, 1 << 32, size=(model.PARTITION_KEYS,), dtype=np.uint64)
        k = k.astype(np.uint32)
        b, h = model.sort_partition(k)
        assert b.shape == (model.PARTITION_KEYS,)
        assert h.shape == (256,)
        eb, eh = ref.partition_ref(k)
        np.testing.assert_array_equal(np.asarray(b), eb)
        np.testing.assert_array_equal(np.asarray(h), eh)


class TestAot:
    def test_checksum_hlo_lowers(self):
        lowered = jax.jit(model.digest_verify).lower(*model.checksum_spec())
        text = to_hlo_text(lowered)
        assert "ENTRY" in text and len(text) > 100

    def test_partition_hlo_lowers(self):
        lowered = jax.jit(model.sort_partition).lower(*model.partition_spec())
        text = to_hlo_text(lowered)
        assert "ENTRY" in text and len(text) > 100

    def test_hlo_deterministic(self):
        """Two lowerings must produce identical artifacts (stable builds)."""
        l1 = to_hlo_text(jax.jit(model.digest_verify).lower(*model.checksum_spec()))
        l2 = to_hlo_text(jax.jit(model.digest_verify).lower(*model.checksum_spec()))
        assert l1 == l2
