"""pytest: Pallas kernels vs pure-numpy oracles — the CORE correctness signal.

Exact integer equality is asserted everywhere (the kernels are integer
kernels; there is no tolerance to hide behind).  hypothesis sweeps shapes
and value distributions.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.checksum import BLOCK_TILE, MOD, checksum_blocks
from compile.kernels.partition import KEY_TILE, NUM_BUCKETS, partition_keys


def u32(rng, shape):
    return rng.integers(0, 1 << 32, size=shape, dtype=np.uint64).astype(np.uint32)


# ---------------------------------------------------------------- checksum

class TestChecksum:
    def test_zeros(self):
        w = np.zeros((BLOCK_TILE, 128), dtype=np.uint32)
        out = np.asarray(checksum_blocks(w))
        assert (out == 0).all()

    def test_ones(self):
        nw = 128
        w = np.ones((BLOCK_TILE, nw), dtype=np.uint32)
        out = np.asarray(checksum_blocks(w))
        exp = ref.checksum_ref(w)
        np.testing.assert_array_equal(out, exp)
        # closed form: s1 = nw, s2 = nw(nw+1)/2
        assert out[0, 0] == nw
        assert out[0, 1] == nw * (nw + 1) // 2

    def test_max_values(self):
        """All-0xFFFFFFFF words stress the mod-P folding."""
        w = np.full((BLOCK_TILE, 256), 0xFFFFFFFF, dtype=np.uint32)
        np.testing.assert_array_equal(
            np.asarray(checksum_blocks(w)), ref.checksum_ref_vec(w)
        )

    def test_values_equal_p(self):
        """Words == P must canonicalize to 0."""
        w = np.full((BLOCK_TILE, 128), MOD, dtype=np.uint32)
        out = np.asarray(checksum_blocks(w))
        assert (out == 0).all()

    def test_random_vs_scalar_oracle(self):
        rng = np.random.default_rng(0)
        w = u32(rng, (BLOCK_TILE, 64))
        np.testing.assert_array_equal(
            np.asarray(checksum_blocks(w)), ref.checksum_ref(w)
        )

    def test_order_sensitivity(self):
        """Swapping two words must change s2 (the digest-integrity property)."""
        rng = np.random.default_rng(1)
        w = u32(rng, (BLOCK_TILE, 128))
        a = np.asarray(checksum_blocks(w))
        w2 = w.copy()
        w2[:, [3, 77]] = w2[:, [77, 3]]
        b = np.asarray(checksum_blocks(w2))
        # only identical-word swaps would collide; rng makes that measure-0
        assert (a[:, 1] != b[:, 1]).all()

    def test_multi_tile_grid(self):
        rng = np.random.default_rng(2)
        w = u32(rng, (BLOCK_TILE * 7, 96))
        np.testing.assert_array_equal(
            np.asarray(checksum_blocks(w)), ref.checksum_ref_vec(w)
        )

    def test_4kb_block_shape(self):
        """The production AOT shape: 64 blocks x 1024 words."""
        rng = np.random.default_rng(3)
        w = u32(rng, (64, 1024))
        np.testing.assert_array_equal(
            np.asarray(checksum_blocks(w)), ref.checksum_ref_vec(w)
        )

    @settings(deadline=None, max_examples=25)
    @given(
        tiles=st.integers(1, 4),
        words=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, tiles, words, seed):
        rng = np.random.default_rng(seed)
        w = u32(rng, (BLOCK_TILE * tiles, words))
        np.testing.assert_array_equal(
            np.asarray(checksum_blocks(w)), ref.checksum_ref_vec(w)
        )

    @settings(deadline=None, max_examples=15)
    @given(
        data=st.lists(
            st.integers(0, 2**32 - 1), min_size=8, max_size=64
        ),
    )
    def test_hypothesis_adversarial_values(self, data):
        """Adversarial word values (hypothesis shrinks toward boundaries)."""
        nw = len(data)
        w = np.tile(np.array(data, dtype=np.uint32), (BLOCK_TILE, 1))
        np.testing.assert_array_equal(
            np.asarray(checksum_blocks(w)), ref.checksum_ref_vec(w)
        )

    def test_rejects_unaligned_blocks(self):
        w = np.zeros((BLOCK_TILE + 1, 8), dtype=np.uint32)
        with pytest.raises(AssertionError):
            checksum_blocks(w)

    def test_int32_and_uint32_inputs_agree(self):
        rng = np.random.default_rng(4)
        w = u32(rng, (BLOCK_TILE, 32))
        a = np.asarray(checksum_blocks(w))
        b = np.asarray(checksum_blocks(w.view(np.int32)))
        np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------- partition

class TestPartition:
    def test_uniform_keys(self):
        rng = np.random.default_rng(0)
        k = u32(rng, (KEY_TILE * 4,))
        b, h = partition_keys(k)
        eb, eh = ref.partition_ref(k)
        np.testing.assert_array_equal(np.asarray(b), eb)
        np.testing.assert_array_equal(np.asarray(h), eh)

    def test_histogram_sums_to_n(self):
        rng = np.random.default_rng(1)
        k = u32(rng, (KEY_TILE * 8,))
        _, h = partition_keys(k)
        assert int(np.asarray(h).sum()) == KEY_TILE * 8

    def test_all_zero_keys(self):
        k = np.zeros((KEY_TILE,), dtype=np.uint32)
        b, h = partition_keys(k)
        assert (np.asarray(b) == 0).all()
        assert int(np.asarray(h)[0]) == KEY_TILE
        assert int(np.asarray(h)[1:].sum()) == 0

    def test_all_max_keys(self):
        k = np.full((KEY_TILE,), 0xFFFFFFFF, dtype=np.uint32)
        b, h = partition_keys(k)
        assert (np.asarray(b) == NUM_BUCKETS - 1).all()
        assert int(np.asarray(h)[-1]) == KEY_TILE

    def test_bucket_boundaries(self):
        """Keys exactly at bucket-range boundaries."""
        step = 1 << (32 - 8)
        ks = []
        for bkt in range(NUM_BUCKETS):
            ks += [bkt * step, bkt * step + step - 1]
        pad = KEY_TILE - (len(ks) % KEY_TILE)
        k = np.array(ks + [0] * pad, dtype=np.uint32)
        b, _ = partition_keys(k)
        b = np.asarray(b)
        for i, bkt in enumerate(range(NUM_BUCKETS)):
            assert b[2 * i] == bkt
            assert b[2 * i + 1] == bkt

    def test_production_shape(self):
        """The AOT shape: 65536 keys."""
        rng = np.random.default_rng(2)
        k = u32(rng, (65536,))
        b, h = partition_keys(k)
        eb, eh = ref.partition_ref(k)
        np.testing.assert_array_equal(np.asarray(b), eb)
        np.testing.assert_array_equal(np.asarray(h), eh)

    @settings(deadline=None, max_examples=20)
    @given(tiles=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, tiles, seed):
        rng = np.random.default_rng(seed)
        k = u32(rng, (KEY_TILE * tiles,))
        b, h = partition_keys(k)
        eb, eh = ref.partition_ref(k)
        np.testing.assert_array_equal(np.asarray(b), eb)
        np.testing.assert_array_equal(np.asarray(h), eh)

    @settings(deadline=None, max_examples=10)
    @given(
        skew=st.sampled_from(["low", "high", "two-point"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_skewed_distributions(self, skew, seed):
        """Non-uniform key distributions (Indy keys are uniform; be stricter)."""
        rng = np.random.default_rng(seed)
        n = KEY_TILE * 2
        if skew == "low":
            k = rng.integers(0, 1 << 16, size=n, dtype=np.uint64)
        elif skew == "high":
            k = rng.integers((1 << 32) - (1 << 16), 1 << 32, size=n, dtype=np.uint64)
        else:
            k = rng.choice(np.array([0, 0xFFFFFFFF], dtype=np.uint64), size=n)
        k = k.astype(np.uint32)
        b, h = partition_keys(k)
        eb, eh = ref.partition_ref(k)
        np.testing.assert_array_equal(np.asarray(b), eb)
        np.testing.assert_array_equal(np.asarray(h), eh)

    def test_rejects_unaligned(self):
        with pytest.raises(AssertionError):
            partition_keys(np.zeros((KEY_TILE + 3,), dtype=np.uint32))
