"""L2: the jax compute graphs the rust runtime executes, calling kernels.*.

Assise is a storage-systems paper — the bulk of the contribution lives in
the L3 rust coordinator — so L2 is deliberately thin (per the architecture
notes): it defines the two data-plane computations Assise performs on bulk
payload bytes, both of which call the L1 Pallas kernels:

- ``digest_verify``: batched block-integrity checksums computed when a
  SharedFS replica verifies a chain-replicated update log before digesting
  it (paper §3.3 "Each replica checks log integrity", §3.2 "checking ...
  data integrity upon eviction").

- ``sort_partition``: the range-partition histogram + bucket assignment of
  MinuteSort step 1 (paper §5.3, Tencent Sort) — one call per input chunk.

Both are lowered ONCE by aot.py to HLO text; python never runs at request
time.  Shapes are fixed at AOT time (PJRT executables are monomorphic);
the rust side pads the final partial batch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.checksum import checksum_blocks
from compile.kernels.partition import partition_keys

# AOT shapes — keep in sync with rust/src/runtime/mod.rs.
CHECKSUM_BLOCKS = 64     # blocks per executable call
CHECKSUM_WORDS = 1024    # 32-bit words per block = 4 KB blocks
PARTITION_KEYS = 65536   # keys per executable call


def digest_verify(words: jnp.ndarray) -> tuple[jnp.ndarray]:
    """(CHECKSUM_BLOCKS, CHECKSUM_WORDS) int32 -> ((CHECKSUM_BLOCKS, 2) int32,).

    Returned as a 1-tuple: aot.py lowers with return_tuple=True and the
    rust side unwraps the tuple.
    """
    return (checksum_blocks(words),)


def sort_partition(keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(PARTITION_KEYS,) int32 -> (bucket ids (N,) int32, hist (256,) int32)."""
    buckets, hist = partition_keys(keys)
    return buckets, hist


def checksum_spec() -> tuple[jax.ShapeDtypeStruct, ...]:
    return (jax.ShapeDtypeStruct((CHECKSUM_BLOCKS, CHECKSUM_WORDS), jnp.int32),)


def partition_spec() -> tuple[jax.ShapeDtypeStruct, ...]:
    return (jax.ShapeDtypeStruct((PARTITION_KEYS,), jnp.int32),)
