"""L1 Pallas kernel: range-partition for the MinuteSort presort (paper §5.3).

Tencent Sort step 1 range-partitions records by their 10-byte key prefix
into per-destination buckets.  The hot-spot is: for a tile of keys, compute
the destination bucket of every key and a histogram of bucket occupancy
(the histogram drives how much space each destination temp file needs).

Bucket function: uniform range split of the 32-bit key prefix into
NUM_BUCKETS = 2**b equal ranges, i.e. bucket = key >> (32 - b).  MinuteSort
Indy keys are uniform random, so equal ranges balance.

TPU mapping (DESIGN.md §Hardware-Adaptation): a GPU implementation would
scatter-add into shared-memory histograms per threadblock.  Scatter is the
wrong primitive on TPU; instead we build a one-hot matrix
(TILE × NUM_BUCKETS) in f32 and reduce it with a matmul against a ones
vector — the histogram becomes an MXU systolic reduction.  BlockSpec
streams the key array HBM→VMEM in TILE-sized chunks and accumulates the
histogram across grid steps in the output block (revisited at every step,
standard Pallas accumulation pattern).

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_BUCKETS = 256
BUCKET_BITS = 8
KEY_TILE = 2048


def _partition_kernel(keys_ref, buckets_ref, hist_ref):
    step = pl.program_id(0)
    keys = keys_ref[...].astype(jnp.uint32)
    b = (keys >> jnp.uint32(32 - BUCKET_BITS)).astype(jnp.int32)
    buckets_ref[...] = b

    # One-hot (TILE, NUM_BUCKETS) and reduce over the tile axis with a
    # matmul: ones(1, TILE) @ onehot -> (1, NUM_BUCKETS).  f32 is exact for
    # counts < 2^24, far above any tile count (TILE = 2048).
    onehot = (b[:, None] == jnp.arange(NUM_BUCKETS, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(jnp.float32)
    ones = jnp.ones((1, keys.shape[0]), dtype=jnp.float32)
    counts = jnp.dot(ones, onehot, preferred_element_type=jnp.float32)

    # Accumulate across grid steps: the hist block maps every step to the
    # same (1, NUM_BUCKETS) window.
    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += counts.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def partition_keys(keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """keys (N,) int32/uint32 -> (bucket_ids (N,) int32, hist (NUM_BUCKETS,) int32).

    N must be a multiple of KEY_TILE (callers pad with key 0xFFFFFFFF and
    subtract pad counts from the last bucket, or just pad with real work).
    """
    (n,) = keys.shape
    assert n % KEY_TILE == 0, f"N {n} not multiple of {KEY_TILE}"
    grid = (n // KEY_TILE,)
    buckets, hist = pl.pallas_call(
        _partition_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((KEY_TILE,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((KEY_TILE,), lambda i: (i,)),
            pl.BlockSpec((1, NUM_BUCKETS), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((1, NUM_BUCKETS), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(keys.astype(jnp.int32))
    return buckets, hist[0]
