"""Pure-jnp/numpy oracles for the L1 Pallas kernels.

These are the CORE correctness signal: pytest asserts the Pallas kernels
(and, transitively, the AOT-exported HLO the rust runtime executes) match
these definitions bit-for-bit.  Written in the most obvious way possible —
python ints are unbounded, so the checksum oracle needs no overflow games.
"""

from __future__ import annotations

import numpy as np

MOD = (1 << 31) - 1
NUM_BUCKETS = 256
BUCKET_BITS = 8


def checksum_ref(words: np.ndarray) -> np.ndarray:
    """Fletcher pair per block over uint32 words; (nb, W) -> (nb, 2) int32.

    s1 = sum(w_i mod P) mod P
    s2 = sum((w_i mod P) * ((i+1) mod P)) mod P

    Scalar python-int loop: unbounded ints, no overflow possible.
    """
    w = np.asarray(words).astype(np.uint64) & 0xFFFFFFFF
    nb, nw = w.shape
    out = np.zeros((nb, 2), dtype=np.int64)
    for b in range(nb):
        s1 = 0
        s2 = 0
        for i in range(nw):
            wm = int(w[b, i]) % MOD
            s1 = (s1 + wm) % MOD
            s2 = (s2 + wm * ((i + 1) % MOD)) % MOD
        out[b, 0] = s1
        out[b, 1] = s2
    return out.astype(np.int32)


def checksum_ref_vec(words: np.ndarray) -> np.ndarray:
    """Vectorized oracle (uint64 math, exact): used for larger sweeps.

    Each product (w mod P) * (weight mod P) < 2^62 fits uint64 exactly.
    """
    w = (np.asarray(words).astype(np.uint64) & 0xFFFFFFFF) % MOD
    nw = w.shape[1]
    weights = np.arange(1, nw + 1, dtype=np.uint64) % MOD
    s1 = w.sum(axis=1, dtype=np.uint64) % MOD  # nw * P < 2^64 for nw < 2^33
    s2 = ((w * weights[None, :]) % MOD).sum(axis=1, dtype=np.uint64) % MOD
    return np.stack([s1, s2], axis=-1).astype(np.int32)


def partition_ref(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(N,) uint32 -> (bucket ids (N,) int32, histogram (256,) int32)."""
    k = np.asarray(keys).astype(np.uint64) & 0xFFFFFFFF
    b = (k >> np.uint64(32 - BUCKET_BITS)).astype(np.int32)
    hist = np.bincount(b, minlength=NUM_BUCKETS).astype(np.int32)
    return b, hist
