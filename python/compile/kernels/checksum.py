"""L1 Pallas kernel: block integrity checksums for the SharedFS digest path.

Assise's SharedFS "checks log integrity" when digesting a LibFS update log
(paper §3.3, §A.1) and "checks permissions and data integrity upon
eviction" (§3.2).  The hot-spot is a batched per-block checksum over the
log payload.  We compute a Fletcher-style pair per 4 KB block:

    s1 = sum(w_i)            mod P
    s2 = sum((i+1) * w_i)    mod P        (position-weighted)

over the block's 32-bit words, with P = 2**31 - 1 (Mersenne prime).  The
position weighting makes the checksum order-sensitive, which is what the
digest needs: a replica whose RDMA-delivered log bytes were reordered or
torn must not validate.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles the
(num_blocks, words) payload into VMEM blocks of (BLOCK_TILE, words) and
reduces along the word axis on the VPU — the word axis is a multiple of
128 lanes, the block axis is the sublane axis.  interpret=True is
mandatory here: the CPU PJRT plugin cannot execute Mosaic custom-calls.

All arithmetic is done in float64-free integer space: jnp.int64 is not
enabled by default, so we accumulate in two int32 lanes using a
split-accumulate (values are masked to 16-bit halves) that is exactly
representable and matches ref.checksum_ref bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mersenne prime 2^31 - 1: lets us reduce "x mod P" with shifts/adds and
# keeps every intermediate inside uint32 when accumulated carefully.
MOD = (1 << 31) - 1

# Tile of blocks processed per pallas grid step.  Chosen so a tile of
# (BLOCK_TILE, 1024) uint32 = 32 KB stays far under VMEM (~16 MB) even with
# double buffering; on real TPU this would leave room to scale words up.
BLOCK_TILE = 8


def _mod_p(x: jnp.ndarray) -> jnp.ndarray:
    """x mod (2^31-1) for non-negative x < 2^62, in uint32-pair-free form.

    Operates on uint32 values interpreted as < 2^32: fold the top bit(s)
    down twice ((x >> 31) + (x & P) < 2^32 always, and a second fold lands
    in [0, P]).
    """
    x = (x >> 31) + (x & MOD)
    x = (x >> 31) + (x & MOD)
    # x may equal P exactly; canonicalize.
    return jnp.where(x == MOD, 0, x)


def _checksum_tile(words: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Fletcher pair for a (tile, W) uint32 payload; returns (tile, 2).

    Accumulation strategy: process the word axis in a fori_loop of
    lane-sized chunks, keeping running (s1, s2) in uint32 reduced mod P at
    every step so nothing overflows.  Each step adds one word column group:
    uint32 word w is first reduced mod P (w < 2^32 so one fold), then
    s1 += w; s2 += (i+1)*w.  The product (i+1)*w can reach 2^62, so it is
    split into 16-bit halves: (i+1)*w = hi*2^16 + lo with hi, lo < 2^47 —
    still too big for uint32, so instead we reduce w mod P first
    (w < 2^31) and multiply by the weight already reduced mod P using a
    16-bit schoolbook split, all in uint64-free uint32 ops.
    """
    w = words.astype(jnp.uint32)
    wmod = _mod_p(w)  # < 2^31
    # weight column vector already in [0, P)
    k = weights.astype(jnp.uint32)

    # 16-bit split multiply: a*b mod P with a,b < 2^31.
    # a = a1*2^16 + a0;  a*b = a1*b*2^16 + a0*b.
    # a1 < 2^15, b < 2^31 -> a1*b < 2^46: still overflows u32.
    # So split b too: b = b1*2^16 + b0.
    #   a*b = (a1*b1)*2^32 + (a1*b0 + a0*b1)*2^16 + a0*b0
    # mod P, 2^32 ≡ 2 and 2^16 stays 2^16 (< P).  Each partial product is
    # < 2^31 (15/16-bit × 16-bit), safe in u32; reduce as we accumulate.
    a = wmod
    b = k
    a1, a0 = a >> 16, a & 0xFFFF
    b1, b0 = b >> 16, b & 0xFFFF
    p_hh = _mod_p(a1 * b1 * jnp.uint32(2))          # *2^32 ≡ *2
    mid = a1 * b0 + a0 * b1                          # < 2^32, fold
    mid = _mod_p(mid)
    # mid * 2^16 mod P: split mid (< 2^31) into 15+16 bits again.
    m1, m0 = mid >> 15, mid & 0x7FFF
    # mid*2^16 = m1*2^31 + m0*2^16 ≡ m1 + m0*2^16 (2^31 ≡ 1 mod P)
    p_mid = _mod_p(m1 + (m0 << 16))
    p_ll = _mod_p(a0 * b0)
    prod = _mod_p(p_hh + p_mid)
    prod = _mod_p(prod + p_ll)

    s1 = wmod
    s2 = prod
    # reduce along word axis with pairwise folds (tree stays < 2^32 because
    # we _mod_p after every addition of two < P terms).
    def tree_reduce(v):
        n = v.shape[-1]
        while n > 1:
            half = n // 2
            lo = v[..., :half]
            hi = v[..., half : 2 * half]
            v = _mod_p(lo + hi) if n % 2 == 0 else jnp.concatenate(
                [_mod_p(lo + hi), v[..., 2 * half :]], axis=-1
            )
            n = v.shape[-1]
        return v[..., 0]

    return jnp.stack([tree_reduce(s1), tree_reduce(s2)], axis=-1)


def _checksum_kernel(words_ref, weights_ref, out_ref):
    words = words_ref[...]
    weights = weights_ref[...]
    out_ref[...] = _checksum_tile(words, weights).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def checksum_blocks(words: jnp.ndarray) -> jnp.ndarray:
    """Pallas entry: words (num_blocks, W) int32/uint32 -> (num_blocks, 2) int32.

    num_blocks must be a multiple of BLOCK_TILE (callers pad); W arbitrary
    but ≥ 1 (128-multiples vectorize best on TPU).
    """
    nb, nw = words.shape
    assert nb % BLOCK_TILE == 0, f"num_blocks {nb} not multiple of {BLOCK_TILE}"
    weights = (jnp.arange(1, nw + 1, dtype=jnp.uint32) % MOD)[None, :].astype(
        jnp.int32
    )
    grid = (nb // BLOCK_TILE,)
    return pl.pallas_call(
        _checksum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_TILE, nw), lambda i: (i, 0)),
            pl.BlockSpec((1, nw), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_TILE, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 2), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(words.astype(jnp.int32), weights)
