"""AOT: lower the L2 jax graphs to HLO *text* for the rust PJRT runtime.

HLO text — NOT ``.serialize()`` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); never at request time.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "checksum": (model.digest_verify, model.checksum_spec),
    "partition": (model.sort_partition, model.partition_spec),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument(
        "--out",
        default=None,
        help="legacy single-output path; writes checksum HLO there and the "
        "rest next to it",
    )
    args = ap.parse_args()

    if args.out_dir:
        out_dir = args.out_dir
    elif args.out:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    else:
        out_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, spec_fn) in ARTIFACTS.items():
        spec = spec_fn()
        lowered = jax.jit(fn).lower(*spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "path": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": s.dtype.name} for s in spec
            ],
            "chars": len(text),
        }
        print(f"wrote {len(text):>8} chars -> {path}")

    # Legacy single-file alias so stale Makefile targets still see a file.
    if args.out:
        with open(os.path.abspath(args.out), "w") as f:
            f.write(open(os.path.join(out_dir, "checksum.hlo.txt")).read())

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest -> {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
